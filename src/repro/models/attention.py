"""Attention: GQA/MHA with RoPE / M-RoPE, qk-norm, sliding windows,
full-sequence (train/prefill) and cached single-token (decode) paths.

The jnp einsum formulation is the reference path (and what the dry-run
lowers); a Pallas flash-attention kernel (repro/kernels/flash_attention.py)
is the TPU production path, toggled via ``params.set_use_pallas``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import constrain
from repro.models import rotary
from repro.models.params import (Builder, apply_linear, head_rms_norm,
                                 softcap, use_pallas)

NEG_INF = -1e30


def init_attention(b: Builder, cfg: ModelConfig, stack: Tuple[int, ...] = (),
                   cross: bool = False) -> None:
    heads_ax = "heads" if cfg.shard_attn_heads else "fsdp"
    kv_ax = "kv_heads" if cfg.shard_attn_heads else "fsdp"
    bias = cfg.family == "vlm"   # qwen2-vl carries qkv bias
    b.linear("wq", cfg.d_model, cfg.q_dim, ("fsdp", heads_ax), stack, bias=bias)
    b.linear("wk", cfg.d_model, cfg.kv_dim, ("fsdp", kv_ax), stack, bias=bias)
    b.linear("wv", cfg.d_model, cfg.kv_dim, ("fsdp", kv_ax), stack, bias=bias)
    b.linear("wo", cfg.q_dim, cfg.d_model, (heads_ax, "fsdp"), stack,
             scale=0.02 / max(1, cfg.n_layers) ** 0.5)
    if cfg.qk_norm and not cross:
        b.ones("q_norm", (*stack, cfg.head_dim), ((None,) * len(stack)) + (None,))
        b.ones("k_norm", (*stack, cfg.head_dim), ((None,) * len(stack)) + (None,))


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def _qkv(p: Dict, cfg: ModelConfig, x: jax.Array,
         angles: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = _split_heads(apply_linear(p["wq"], x), cfg.n_heads, cfg.head_dim)
    k = _split_heads(apply_linear(p["wk"], x), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(apply_linear(p["wv"], x), cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm and "q_norm" in p:
        q = head_rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = head_rms_norm(p["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = rotary.apply_rope(q, angles)
        k = rotary.apply_rope(k, angles)
    return q, k, v


def _sdpa(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
          mask: jax.Array) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,K,hd), mask: broadcastable (B,1,S,T) bool.
    Grouped-query: H = K*G. Returns (B,S,H*hd)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = q.reshape(B, S, K, G, hd)
    # keep bf16 inputs, fp32 accumulation: numerically identical to
    # upcasting (bf16->f32 is exact) but never materializes fp32 copies of
    # the KV cache (§Perf cell-A finding)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)   # mask (B,K?,S,T)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype).reshape(B, S, H * hd)


# Use the chunked (flash-style) path once the score matrix would exceed
# this many elements per (batch, head) — beyond it, materializing S×T
# scores dominates the memory roofline term.
FLASH_THRESHOLD = 1024 * 2048


def _tile_mask(qi, ki, bq: int, bk: int, causal: bool, window: int):
    qpos = qi * bq + jnp.arange(bq)[:, None]
    kpos = ki * bk + jnp.arange(bk)[None, :]
    msk = jnp.ones((bq, bk), dtype=bool)
    if causal:
        msk &= kpos <= qpos
    if window:
        msk &= kpos > qpos - window
    return msk


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _flash_core(causal: bool, window: int, bq: int, bk: int, G: int,
                qg: jax.Array, k: jax.Array, v: jax.Array):
    """Flash attention core. qg: (B,S,K,G,hd) PRE-SCALED fp32;
    k/v: (B,T,K,hd) fp32. Returns out (B,S,K,G,hd) fp32."""
    out, _ = _flash_fwd_pass(causal, window, bq, bk, qg, k, v)
    return out


def _tile_pairs(nq: int, nk: int, bq: int, bk: int, causal: bool,
                window: int):
    """Static enumeration of (q-tile, kv-tile) pairs with any live entry —
    fully-masked tiles are never visited (causal: ~2× fewer; sliding
    window: O(S·window) instead of O(S²))."""
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * bq, qi * bq + bq - 1
        for ki in range(nk):
            k_lo, k_hi = ki * bk, ki * bk + bk - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi < q_lo - window + 1:
                continue
            pairs.append((qi, ki))
    return pairs


def _flash_fwd_pass(causal, window, bq, bk, qg, k, v):
    B, S, K, G, hd = qg.shape
    T = k.shape[1]
    nq, nk = S // bq, T // bk
    qc = jnp.moveaxis(qg.reshape(B, nq, bq, K, G, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, bk, K, hd), 1, 0)

    pairs = _tile_pairs(nq, nk, bq, bk, causal, window)
    qi_a = jnp.array([p[0] for p in pairs], dtype=jnp.int32)
    ki_a = jnp.array([p[1] for p in pairs], dtype=jnp.int32)
    first = jnp.array([i == 0 or pairs[i][0] != pairs[i - 1][0]
                       for i in range(len(pairs))])
    last = jnp.array([i == len(pairs) - 1 or pairs[i][0] != pairs[i + 1][0]
                      for i in range(len(pairs))])

    m0 = jnp.full((B, K, G, bq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, K, G, bq), dtype=jnp.float32)
    a0 = jnp.zeros((B, K, G, bq, hd), dtype=jnp.float32)
    out0 = jnp.zeros((nq, B, K, G, bq, hd), dtype=jnp.float32)
    lse0 = jnp.zeros((nq, B, K, G, bq), dtype=jnp.float32)

    def step(carry, xs):
        m, l, acc, outb, lseb = carry
        qi, ki, fst, lst = xs
        qb = jax.lax.dynamic_index_in_dim(qc, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, ki, 0, keepdims=False)
        m = jnp.where(fst, m0, m)
        l = jnp.where(fst, l0, l)
        acc = jnp.where(fst, a0, acc)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qb, kb,
                       preferred_element_type=jnp.float32)
        msk = _tile_mask(qi, ki, bq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        lq = jnp.maximum(l_new, 1e-30)
        tile_out = acc_new / lq[..., None]
        tile_lse = m_new + jnp.log(lq)
        cur_o = jax.lax.dynamic_index_in_dim(outb, qi, 0, keepdims=False)
        cur_s = jax.lax.dynamic_index_in_dim(lseb, qi, 0, keepdims=False)
        outb = jax.lax.dynamic_update_index_in_dim(
            outb, jnp.where(lst, tile_out, cur_o), qi, 0)
        lseb = jax.lax.dynamic_update_index_in_dim(
            lseb, jnp.where(lst, tile_lse, cur_s), qi, 0)
        return (m_new, l_new, acc_new, outb, lseb), None

    (_, _, _, outs, lses), _ = jax.lax.scan(
        step, (m0, l0, a0, out0, lse0), (qi_a, ki_a, first, last))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, K, G, S, hd)      # (B,K,G,S,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, K, G, hd)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, K, G, S)
    return out, lse


def _flash_fwd_rule(causal, window, bq, bk, G, qg, k, v):
    out, lse = _flash_fwd_pass(causal, window, bq, bk, qg, k, v)
    return out, (qg, k, v, out, lse)


def _flash_bwd_rule(causal, window, bq, bk, G, res, dout):
    """FlashAttention-2-style backward: probabilities are recomputed per
    tile from (q, k, lse); nothing S×T ever materializes. Two passes:
    k-outer for (dk, dv), q-outer for dq."""
    qg, k, v, out, lse = res
    B, S, K, Gd, hd = qg.shape
    T = k.shape[1]
    nq, nk = S // bq, T // bk
    D = jnp.sum(dout * out, axis=-1)                      # (B,S,K,G)
    Dr = jnp.moveaxis(D.reshape(B, S, K, Gd), 1, 3)       # (B,K,G,S)
    do_r = jnp.moveaxis(dout, 1, 3)                       # (B,K,G,S,hd)

    qc = jnp.moveaxis(qg.reshape(B, nq, bq, K, Gd, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, bk, K, hd), 1, 0)
    lse_c = jnp.moveaxis(lse.reshape(B, K, Gd, nq, bq), 3, 0)   # (nq,B,K,G,bq)
    D_c = jnp.moveaxis(Dr.reshape(B, K, Gd, nq, bq), 3, 0)
    do_c = jnp.moveaxis(do_r.reshape(B, K, Gd, nq, bq, hd), 3, 0)

    def p_tile(qb, kb, lse_b, qi, ki):
        s = jnp.einsum("bqkgh,btkh->bkgqt", qb, kb,
                       preferred_element_type=jnp.float32)
        msk = _tile_mask(qi, ki, bq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        return jnp.exp(s - lse_b[..., None])              # (B,K,G,bq,bk)

    def idx(a, i):
        return jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)

    # ---- pass 1: dk, dv (pairs grouped by k tile) -------------------------
    pairs_k = sorted(_tile_pairs(nq, nk, bq, bk, causal, window),
                     key=lambda p: (p[1], p[0]))
    qi_k = jnp.array([p[0] for p in pairs_k], dtype=jnp.int32)
    ki_k = jnp.array([p[1] for p in pairs_k], dtype=jnp.int32)
    fst_k = jnp.array([i == 0 or pairs_k[i][1] != pairs_k[i - 1][1]
                       for i in range(len(pairs_k))])
    lst_k = jnp.array([i == len(pairs_k) - 1
                       or pairs_k[i][1] != pairs_k[i + 1][1]
                       for i in range(len(pairs_k))])
    zk = jnp.zeros((B, bk, K, hd), dtype=jnp.float32)
    dk0 = jnp.zeros((nk, B, bk, K, hd), dtype=jnp.float32)

    def k_step(carry, xs):
        dk_acc, dv_acc, dkb, dvb = carry
        qi, ki, fst, lst = xs
        dk_acc = jnp.where(fst, zk, dk_acc)
        dv_acc = jnp.where(fst, zk, dv_acc)
        qb, kb, vb = idx(qc, qi), idx(kc, ki), idx(vc, ki)
        lse_b, D_b, do_b = idx(lse_c, qi), idx(D_c, qi), idx(do_c, qi)
        p = p_tile(qb, kb, lse_b, qi, ki)
        dv_acc = dv_acc + jnp.einsum("bkgqt,bkgqh->btkh", p, do_b)
        dp = jnp.einsum("bkgqh,btkh->bkgqt", do_b, vb)
        ds = p * (dp - D_b[..., None])
        dk_acc = dk_acc + jnp.einsum("bkgqt,bqkgh->btkh", ds, qb)
        dkb = jax.lax.dynamic_update_index_in_dim(
            dkb, jnp.where(lst, dk_acc, idx(dkb, ki)), ki, 0)
        dvb = jax.lax.dynamic_update_index_in_dim(
            dvb, jnp.where(lst, dv_acc, idx(dvb, ki)), ki, 0)
        return (dk_acc, dv_acc, dkb, dvb), None

    (_, _, dks, dvs), _ = jax.lax.scan(
        k_step, (zk, zk, dk0, dk0), (qi_k, ki_k, fst_k, lst_k))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, T, K, hd)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, T, K, hd)

    # ---- pass 2: dq (pairs grouped by q tile) -----------------------------
    pairs_q = _tile_pairs(nq, nk, bq, bk, causal, window)
    qi_q = jnp.array([p[0] for p in pairs_q], dtype=jnp.int32)
    ki_q = jnp.array([p[1] for p in pairs_q], dtype=jnp.int32)
    fst_q = jnp.array([i == 0 or pairs_q[i][0] != pairs_q[i - 1][0]
                       for i in range(len(pairs_q))])
    lst_q = jnp.array([i == len(pairs_q) - 1
                       or pairs_q[i][0] != pairs_q[i + 1][0]
                       for i in range(len(pairs_q))])
    zq = jnp.zeros((B, bq, K, Gd, hd), dtype=jnp.float32)
    dq0 = jnp.zeros((nq, B, bq, K, Gd, hd), dtype=jnp.float32)

    def q_step(carry, xs):
        dq_acc, dqb = carry
        qi, ki, fst, lst = xs
        dq_acc = jnp.where(fst, zq, dq_acc)
        qb, kb, vb = idx(qc, qi), idx(kc, ki), idx(vc, ki)
        lse_b, D_b, do_b = idx(lse_c, qi), idx(D_c, qi), idx(do_c, qi)
        p = p_tile(qb, kb, lse_b, qi, ki)
        dp = jnp.einsum("bkgqh,btkh->bkgqt", do_b, vb)
        ds = p * (dp - D_b[..., None])
        dq_acc = dq_acc + jnp.einsum("bkgqt,btkh->bqkgh", ds, kb)
        dqb = jax.lax.dynamic_update_index_in_dim(
            dqb, jnp.where(lst, dq_acc, idx(dqb, qi)), qi, 0)
        return (dq_acc, dqb), None

    (_, dqs), _ = jax.lax.scan(q_step, (zq, dq0),
                               (qi_q, ki_q, fst_q, lst_q))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, K, Gd, hd)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_flash_core.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _sdpa_flash_jnp(cfg: ModelConfig, q: jax.Array, k: jax.Array,
                    v: jax.Array, *, causal: bool, window: int,
                    bq: int = 512, bk: int = 1024) -> jax.Array:
    """XLA-native flash attention: nested lax.scan over (q-chunks, k-chunks)
    with an online-softmax carry — the score matrix never materializes
    beyond one (bq × bk) tile per head, in EITHER direction (custom_vjp
    recomputes probability tiles in the backward pass, FlashAttention-2
    style). This is the TPU-honest lowering for long sequences when the
    Pallas kernel is off (dry-run / CPU) and mirrors what the Pallas kernel
    does in VMEM.

    No logit softcap support here — archs with softcap take the _sdpa path.
    """
    assert not cfg.attn_logit_softcap, "flash path has no softcap"
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    # stay in the input dtype (bf16 on TPU) with fp32 accumulation inside
    # the tiles — no fp32 copies of q/k/v ever materialize
    qg = (q.reshape(B, S, K, G, hd) * jnp.asarray(scale, q.dtype))
    out = _flash_core(causal, window, bq, bk, G, qg, k, v)
    return out.reshape(B, S, H * hd).astype(v.dtype)


def full_mask(B: int, S: int, T: int, q_offset, causal: bool,
              window: int = 0) -> jax.Array:
    """(B, 1, S, T) boolean mask. q position i attends kv position j."""
    qpos = jnp.arange(S)[:, None] + q_offset          # absolute q positions
    kpos = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return jnp.broadcast_to(m[None, None], (B, 1, S, T))


def attend_full(p: Dict, cfg: ModelConfig, x: jax.Array,
                angles: Optional[jax.Array], *, causal: bool = True,
                window: int = 0,
                kv: Optional[Tuple[jax.Array, jax.Array]] = None) -> jax.Array:
    """Train/prefill attention over the full sequence (or cross-attention
    when kv=(k_src, v_src) activations are given)."""
    B, S, _ = x.shape
    if kv is None:
        q, k, v = _qkv(p, cfg, x, angles)
        mask = full_mask(B, S, S, 0, causal, window)
    else:
        q = _split_heads(apply_linear(p["wq"], x), cfg.n_heads, cfg.head_dim)
        src_k, src_v = kv
        k = _split_heads(apply_linear(p["wk"], src_k), cfg.n_kv_heads, cfg.head_dim)
        v = _split_heads(apply_linear(p["wv"], src_v), cfg.n_kv_heads, cfg.head_dim)
        if angles is not None:
            q = rotary.apply_rope(q, angles)
        mask = jnp.ones((B, 1, S, k.shape[1]), dtype=bool)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if use_pallas() and kv is None:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cfg.attn_logit_softcap)
        out = out.reshape(B, S, cfg.q_dim)
    elif kv is None and _use_flash_jnp(S, k.shape[1]):
        out = _sdpa_flash_jnp(cfg, q, k, v, causal=causal, window=window)
    else:
        out = _sdpa(cfg, q, k, v, mask)
    out = constrain(out, "batch", None, "heads")
    return apply_linear(p["wo"], out)


def _use_flash_jnp(S: int, T: int, bq: int = 512, bk: int = 1024) -> bool:
    return (S * T >= FLASH_THRESHOLD
            and S % min(bq, S) == 0 and T % min(bk, T) == 0)


# ---------------------------------------------------------------------------
# Decode path (single new token against a cache)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                  dtype) -> Dict:
    """Full cache when window==0, else ring buffer of size window."""
    length = window if window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
    }


def attend_decode(p: Dict, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                  cache: Dict, angles: Optional[jax.Array], *,
                  window: int = 0,
                  cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                  table: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Dict]:
    """x: (B,1,D); pos: (B,) int32 per-sequence positions of the new token
    (-1 marks a dead/purged slot: nothing is written for it and its output
    row is exact zeros). With ``table`` (B, NB) int32 the cache is a paged
    arena — k/v leaves (P, bk, K, hd), logical block j of row b living in
    physical block table[b, j] (full-cache layout only).
    Returns (out, cache)."""
    B = x.shape[0]
    if cross_kv is not None:
        q = _split_heads(apply_linear(p["wq"], x), cfg.n_heads, cfg.head_dim)
        k, v = cross_kv     # precomputed (B, T_enc, K, hd)
        mask = jnp.ones((B, 1, 1, k.shape[1]), dtype=bool)
        out = _sdpa(cfg, q, k, v, mask)
        return apply_linear(p["wo"], out), cache

    q, k_new, v_new = _qkv(p, cfg, x, angles)
    rows = jnp.arange(B)
    if table is not None:
        assert not window, "paged cache is full-layout only"
        P, bkb = cache["k"].shape[0], cache["k"].shape[1]
        NB = table.shape[1]
        safe = jnp.maximum(pos, 0)
        # dead rows (pos < 0) and positions past the table target the
        # sentinel block P: the scatter drops them (OOB + mode='drop')
        pb = jnp.where((pos >= 0) & (safe // bkb < NB),
                       table[rows, jnp.minimum(safe // bkb, NB - 1)], P)
        off = safe % bkb
        k = cache["k"].at[pb, off].set(
            k_new[:, 0].astype(cache["k"].dtype), mode="drop")
        v = cache["v"].at[pb, off].set(
            v_new[:, 0].astype(cache["v"].dtype), mode="drop")
        if use_pallas():
            from repro.kernels import ops as kops
            out = kops.decode_attention_paged(
                q[:, 0], k, v, pos + 1, table,
                softcap=cfg.attn_logit_softcap)
            out = out.reshape(B, 1, cfg.q_dim)
        else:
            # gather the arena back into the contiguous (B, NB*bk) layout:
            # same shapes and values as the contiguous path for every live
            # position, so the einsum results are bit-identical to it
            L = NB * bkb
            kc = k[table].reshape(B, L, *k.shape[2:])
            vc = v[table].reshape(B, L, *v.shape[2:])
            valid = jnp.arange(L)[None, :] <= pos[:, None]
            out = _sdpa(cfg, q, kc, vc, valid[:, None, None, :])
        out = jnp.where((pos >= 0)[:, None, None], out, 0.0)
        out = apply_linear(p["wo"], out)
        return out, {"k": k, "v": v}

    L = cache["k"].shape[1]
    # dead rows (pos = -1) park their write at slot 0 of their own row —
    # masked by length 0 downstream, fully overwritten on slot reuse
    slot = jnp.mod(pos, L) if window else jnp.maximum(pos, 0)  # (B,)
    k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    k = constrain(k, "batch", "kv_seq" if not window else None, None, None)
    v = constrain(v, "batch", "kv_seq" if not window else None, None, None)
    if use_pallas():
        # ragged decode kernel: per-slot lengths, block-skipped dead cache
        from repro.kernels import ops as kops
        out = kops.decode_attention(q[:, 0], k, v, pos + 1, window=window,
                                    softcap=cfg.attn_logit_softcap)
        out = out.reshape(B, 1, cfg.q_dim)
    else:
        kpos = jnp.arange(L)[None, :]                  # (1, L)
        pcol = pos[:, None]
        if window:
            # ring buffer: valid slots hold positions in (pos-window, pos]
            age = jnp.mod(pcol - kpos, L)
            valid = age < jnp.minimum(pcol + 1, L)
        else:
            valid = kpos <= pcol
        mask = valid[:, None, None, :]                 # (B,1,1,L)
        out = _sdpa(cfg, q, k, v, mask)
        # dead rows have an all-masked score row; match the kernel's
        # exact-zero emit instead of softmax-uniform junk
        out = jnp.where((pos >= 0)[:, None, None], out, 0.0)
    out = apply_linear(p["wo"], out)
    return out, {"k": k, "v": v}


def _cache_slots(k: jax.Array, lengths: jax.Array, L: int,
                 window: int) -> jax.Array:
    """Gather prefill K (or V) into the decode-cache slot layout.

    Full cache (window=0): slot s holds position s; live iff s < len.
    Ring: slot s (< window) holds the LATEST position p ≡ s (mod window)
    with p < len. A gather (one source position per slot, per row) instead
    of the old scatter, so per-row ragged lengths cost nothing extra.
    k: (B, S, K, hd) -> (B, L, K, hd)."""
    B, S = k.shape[0], k.shape[1]
    s = jnp.arange(L)[None, :]                               # (1, L)
    if window:
        cycles = (lengths[:, None] - 1 - s) // window        # floor div
        p = s + cycles * window
        valid = (p >= 0) & (s < window)
    else:
        p = jnp.broadcast_to(s, (B, L))
        valid = s < lengths[:, None]
    g = jnp.take_along_axis(k, jnp.clip(p, 0, S - 1)[..., None, None],
                            axis=1)
    return jnp.where(valid[..., None, None], g, jnp.zeros_like(g))


def attend_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                   angles: Optional[jax.Array], *, causal: bool = True,
                   window: int = 0, max_len: int = 0,
                   lengths: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, Dict]:
    """Full-sequence attention that also materializes the decode cache.

    Full cache: k/v placed at [0, S) of a (B, max_len, ...) buffer.
    Windowed: ring layout — the last `window` live tokens land at slot
    pos%window. `lengths` (B,) marks per-row live prompt lengths when the
    batch is right-padded to a bucket (continuous-batching admission);
    slots past a row's length are zeroed (and masked during decode).
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, angles)
    if use_pallas():
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=cfg.attn_logit_softcap)
        out = out.reshape(B, S, cfg.q_dim)
    elif _use_flash_jnp(S, S):
        out = _sdpa_flash_jnp(cfg, q, k, v, causal=causal, window=window)
    else:
        mask = full_mask(B, S, S, 0, causal, window)
        out = _sdpa(cfg, q, k, v, mask)
    out = apply_linear(p["wo"], out)

    L = window if window else max_len
    if lengths is None:
        lengths = jnp.full((B,), S, dtype=jnp.int32)
    ck = _cache_slots(k, lengths, L, window).astype(k.dtype)
    cv = _cache_slots(v, lengths, L, window).astype(v.dtype)
    ck = constrain(ck, "batch", "kv_seq" if not window else None, None, None)
    cv = constrain(cv, "batch", "kv_seq" if not window else None, None, None)
    return out, {"k": ck, "v": cv}


def attend_prefill_ext(p: Dict, cfg: ModelConfig, x: jax.Array,
                       angles: Optional[jax.Array], arena: Dict,
                       table: jax.Array, starts: jax.Array,
                       lengths: jax.Array) -> Tuple[jax.Array, Dict]:
    """Tail prefill against a paged prefix (prefix-reuse admission).

    x: (B, St, D) embeds of the UNSHARED tail only — positions start at
    ``starts`` (the caller's rope angles already encode that offset).
    arena: paged k/v leaves (P, bk, K, hd); table: (B, NB) int32 block
    table whose first ``starts[b]`` positions hold the shared prefix;
    starts/lengths: (B,) int32 — prefix length and live TAIL length.

    Each tail query attends [shared prefix | causal tail]. Returns
    (out (B, St, q_dim), tail cache {k,v}: (B, St, K, hd) slot s = tail
    position s, zeroed past ``lengths`` — scatter_paged writes it through
    the table at absolute offsets). jnp path only: prefix-reuse serving is
    admission-rate bound, not prefill-flops bound (DESIGN.md §5.7)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, angles)
    bk = arena["k"].shape[1]
    NB = table.shape[1]
    Lp = NB * bk
    kp = arena["k"][table].reshape(B, Lp, *arena["k"].shape[2:])
    vp = arena["v"][table].reshape(B, Lp, *arena["v"].shape[2:])
    kk = jnp.concatenate([kp.astype(k.dtype), k], axis=1)   # (B, Lp+S, K, hd)
    vv = jnp.concatenate([vp.astype(v.dtype), v], axis=1)
    prefix_ok = jnp.arange(Lp)[None, :] < starts[:, None]   # (B, Lp)
    tail_ok = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]  # (S, S)
    mask = jnp.concatenate([
        jnp.broadcast_to(prefix_ok[:, None, :], (B, S, Lp)),
        jnp.broadcast_to(tail_ok[None], (B, S, S))], axis=2)
    out = _sdpa(cfg, q, kk, vv, mask[:, None])              # (B,1,S,Lp+S)
    out = apply_linear(p["wo"], out)
    ck = _cache_slots(k, lengths, S, 0).astype(k.dtype)
    cv = _cache_slots(v, lengths, S, 0).astype(v.dtype)
    return out, {"k": ck, "v": cv}
