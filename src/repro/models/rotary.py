"""Rotary embeddings: standard RoPE, Qwen2-VL M-RoPE, and sinusoidal
absolute positions (seamless enc-dec)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions: (..., S) int -> angles (..., S, head_dim//2)."""
    freqs = _rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * freqs


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — (t, h, w) component ids (text tokens use t=h=w).
    sections: per-component count of rotary freq pairs, sum == head_dim//2.
    Returns angles (B, S, head_dim//2) with the frequency axis partitioned
    into t/h/w sections.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = _rope_freqs(head_dim, theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (3, B, S, hd/2)
    comp = jnp.repeat(jnp.arange(3), jnp.array(sections),
                      total_repeat_length=head_dim // 2)      # (hd/2,)
    sel = jax.nn.one_hot(comp, 3, dtype=ang.dtype)           # (hd/2, 3)
    return jnp.einsum("cbsf,fc->bsf", ang, sel)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); angles: (B, S, hd//2). Rotates interleaved halves
    (GPT-NeoX convention: first half / second half)."""
    dtype = x.dtype
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    cos = jnp.cos(angles)[..., None, :]   # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(dtype)


def sinusoidal_embed(positions: jax.Array, dim: int,
                     max_wavelength: float = 10_000.0) -> jax.Array:
    """positions (..., S) -> (..., S, dim) sinusoidal absolute embedding."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(max_wavelength)
                   * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def make_positions(batch: int, seq: int,
                   kind: str, offset: jax.Array | int = 0) -> jax.Array:
    """Default position ids. kind=='mrope' -> (3, B, S); else (B, S)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if kind == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos
