"""Chunked linear-recurrence engine (TPU-native).

One engine powers every O(1)-state sequence mixer in the framework:

  * xLSTM mLSTM   — matrix memory ``S_t = f_t S_{t-1} + i_t v_t k_t^T`` with
    stabilized exponential gating and the ``max(|n^T q|, 1)`` normalizer.
  * Mamba-2 / SSD — per-head scalar decay ``S_t = a_t S_{t-1} + (Δu)_t B_t^T``
    read out with C_t (q := C, k := B, v := Δ·u, no input gate / normalizer).

Instead of a per-step ``lax.scan`` (sequential, VPU-bound, and invisible to
XLA cost analysis through the loop trip count), sequences are processed in
chunks of length ``L``: intra-chunk interactions become an (L×L)-masked
matmul pair (MXU work), and only the O(S/L) inter-chunk state recurrence is
scanned. This is the standard chunked linear-attention factorization — exact,
not an approximation.

Numerical stabilization: all gates live in log space. A running max ``m`` is
carried across chunks; the matrix state and normalizer are stored rescaled by
``exp(-m)`` so exponentials stay bounded. The mLSTM denominator
``max(|n^T q|, 1)`` becomes ``max(|ñ^T q|, exp(-m))`` in rescaled
coordinates, which is exact.

Shapes (all functions):
  q : (B, S, H, dk)      k : (B, S, H, dk)      v : (B, S, H, dv)
  log_f : (B, S, H)  per-step log forget gate (must be <= 0 for stability;
                     callers pass log(sigmoid(.)) or Δ·A with A < 0)
  log_i : (B, S, H)  per-step log input gate (unbounded; stabilized here)
State: S (B, H, dv, dk), n (B, H, dk), m (B, H).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


class ScanState(NamedTuple):
    S: jax.Array          # (B, H, dv, dk) rescaled matrix memory
    n: jax.Array          # (B, H, dk)    rescaled normalizer (mLSTM only)
    m: jax.Array          # (B, H)        running log-max stabilizer


def init_state(batch: int, heads: int, dk: int, dv: int,
               dtype=jnp.float32) -> ScanState:
    return ScanState(
        S=jnp.zeros((batch, heads, dv, dk), dtype=dtype),
        n=jnp.zeros((batch, heads, dk), dtype=dtype),
        m=jnp.full((batch, heads), 0.0, dtype=dtype),
    )


def _chunk(x: jax.Array, L: int) -> jax.Array:
    """(B, S, ...) -> (B, S//L, L, ...)."""
    B, S = x.shape[:2]
    return x.reshape(B, S // L, L, *x.shape[2:])


def chunked_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                 log_f: jax.Array, log_i: jax.Array,
                 state: Optional[ScanState] = None,
                 *, chunk: int = 128, normalize: bool = False,
                 ) -> Tuple[jax.Array, ScanState]:
    """Exact chunked linear recurrence. Returns (y (B,S,H,dv), final state).

    y_t = (S_t q_t) / denom_t      with S_t = exp(log_f_t) S_{t-1}
                                          + exp(log_i_t) v_t k_t^T
    denom_t = max(|n_t^T q_t|, 1) when normalize else 1.
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    if S % L:
        pad = L - S % L
        zf = lambda x: jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        # padded steps: forget=1 (log 0), input gate -inf (contribute nothing)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=NEG)
    Sp = q.shape[1]
    if state is None:
        state = init_state(B, H, dk, dv)

    cdt = jnp.float32
    qc = _chunk(q, L).astype(cdt)
    kc = _chunk(k, L).astype(cdt)
    vc = _chunk(v, L).astype(cdt)
    lfc = _chunk(log_f, L).astype(cdt)      # (B, C, L, H)
    lic = _chunk(log_i, L).astype(cdt)

    def step(carry: ScanState, xs):
        qb, kb, vb, lf, li = xs              # (B,L,H,dk), ..., (B,L,H)
        S0, n0, m0 = carry.S, carry.n, carry.m
        F = jnp.cumsum(lf, axis=1)           # (B,L,H) decay chunk-start..j incl
        FL = F[:, -1]                        # (B,H) total chunk decay
        w = li - F                           # source log-weight per step τ
        # per-step stabilizer M_j = max(m0, cummax_{τ<=j} w_τ)
        M = jnp.maximum(m0[:, None], jax.lax.cummax(w, axis=1))   # (B,L,H)
        m_new = jnp.maximum(m0 + FL, jnp.max(w, axis=1) + FL)     # (B,H)

        # ---- intra-chunk attention-style term -----------------------------
        # A[j,τ] = exp(F_j - F_τ + li_τ - (F_j + M_j)) = exp(w_τ - M_j), τ<=j
        # clamp BEFORE exp: masked (future) entries can overflow, and
        # where(mask, inf, 0) poisons the backward pass with inf*0 = NaN.
        logA = w[:, None, :, :] - M[:, :, None, :]       # (B, j, τ, H)
        mask = jnp.tril(jnp.ones((L, L), dtype=bool))
        logA = jnp.where(mask[None, :, :, None], logA, NEG)
        A = jnp.exp(logA)
        qk = jnp.einsum("bjhd,bthd->bjth", qb, kb)        # (B,j,τ,H)
        intra = jnp.einsum("bjth,bthv->bjhv", qk * A, vb)  # (B,L,H,dv)

        # ---- inter-chunk (carried state) term ------------------------------
        # exp(m0 + F_j - m_j) = exp(m0 - M_j)
        carry_w = jnp.exp(m0[:, None] - M)                 # (B,L,H)
        inter = jnp.einsum("bhvd,bjhd->bjhv", S0, qb) * carry_w[..., None]
        num = intra + inter                                # (B,L,H,dv)

        if normalize:
            nk = jnp.einsum("bjth,bthd->bjhd", A, kb)       # Σ_τ A k_τ
            nvec = nk + n0[:, None] * carry_w[..., None]    # (B,L,H,dk)
            dot = jnp.einsum("bjhd,bjhd->bjh", nvec, qb)
            # true m at step j is F_j + M_j
            denom = jnp.maximum(jnp.abs(dot), jnp.exp(-(F + M)))
            y = num / denom[..., None]
        else:
            # undo the exp(-m_j) rescale; for SSD-style gates (log_i = 0,
            # log_f <= 0) m_j == 0 identically, so this is exact and free.
            y = num * jnp.exp(F + M)[..., None]

        # ---- state update ---------------------------------------------------
        sw = jnp.exp(w + FL[:, None] - m_new[:, None])      # (B,L,H)
        S_new = (S0 * jnp.exp(m0 + FL - m_new)[..., None, None]
                 + jnp.einsum("bthv,bthd,bth->bhvd", vb, kb, sw))
        n_new = (n0 * jnp.exp(m0 + FL - m_new)[..., None]
                 + jnp.einsum("bthd,bth->bhd", kb, sw))
        return ScanState(S_new, n_new, m_new), y

    # scan over chunks: move chunk axis first
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, lfc, lic))
    final, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, dv)[:, :S]
    return y.astype(v.dtype), final


def step_scan(q: jax.Array, k: jax.Array, v: jax.Array,
              log_f: jax.Array, log_i: jax.Array,
              state: ScanState, *, normalize: bool = False,
              ) -> Tuple[jax.Array, ScanState]:
    """Single decode step. q/k/v: (B, H, d·); log_f/log_i: (B, H)."""
    S0, n0, m0 = state.S, state.n, state.m
    lf = log_f.astype(jnp.float32)
    li = log_i.astype(jnp.float32)
    m_new = jnp.maximum(m0 + lf, li)
    dec = jnp.exp(m0 + lf - m_new)
    inp = jnp.exp(li - m_new)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    S_new = S0 * dec[..., None, None] + jnp.einsum(
        "bhv,bhd,bh->bhvd", vf, kf, inp)
    n_new = n0 * dec[..., None] + kf * inp[..., None]
    num = jnp.einsum("bhvd,bhd->bhv", S_new, qf)
    if normalize:
        dot = jnp.einsum("bhd,bhd->bh", n_new, qf)
        denom = jnp.maximum(jnp.abs(dot), jnp.exp(-m_new))
        y = num / denom[..., None]
    else:
        y = num * jnp.exp(m_new)[..., None]
    return y.astype(v.dtype), ScanState(S_new, n_new, m_new)


def reference_scan(q, k, v, log_f, log_i, state=None, *, normalize=False):
    """Per-step oracle (O(S) sequential) for tests. Same signature/semantics
    as ``chunked_scan``."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = init_state(B, H, dk, dv)

    def body(st, xs):
        qt, kt, vt, lf, li = xs
        y, st2 = step_scan(qt, kt, vt, lf, li, st, normalize=normalize)
        return st2, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (q, k, v, log_f, log_i))
    final, ys = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), final
