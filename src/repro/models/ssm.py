"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM
(scalar memory, sequential with block-diagonal recurrence).

mLSTM block (xLSTM arXiv:2405.04517, pre-up-projection variant):
    x -> RMSNorm -> up-proj to (e*d) twice: branch u, gate z
      u -> causal conv (k=4, silu) -> q, k projections; v from u directly
      per-head scalar gates i (exp) / f (sigmoid) from the conv'd branch
      mLSTM cell (chunked_scan, normalize=True) -> per-head RMS norm
      -> * silu(z) -> down proj -> residual
sLSTM block:
    x -> RMSNorm -> sLSTM cell (4 gates, block-diagonal recurrence,
    stabilized exponential i/f gating) -> per-head RMS norm -> GeGLU FFN
    (proj factor 4/3) -> residual

Decode paths keep O(1) state per layer: mLSTM (S, n, m) per head; sLSTM
(c, n, h, m).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import constrain
from repro.models import linear_scan as lscan
from repro.models.params import Builder, apply_linear, head_rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def _inner(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = d_inner // cfg.n_heads
    return d_inner, hd


def init_mlstm(b: Builder, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> None:
    d, H = cfg.d_model, cfg.n_heads
    di, hd = _inner(cfg)
    st = (None,) * len(stack)
    b.linear("w_up", d, di, ("fsdp", "ssm_inner"), stack)
    b.linear("w_gate", d, di, ("fsdp", "ssm_inner"), stack)
    b.normal("conv", (*stack, 4, di), (*st, None, "ssm_inner"), scale=0.1)
    b.linear("wq", di, di, ("ssm_inner", None), stack)
    b.linear("wk", di, di, ("ssm_inner", None), stack)
    # per-head scalar gates from the conv'd branch
    b.linear("w_if", di, 2 * H, ("ssm_inner", None), stack)
    bif = jnp.concatenate([jnp.zeros(H), 3.0 * jnp.ones(H)])
    b.sub("gate_bias").const("b_if", jnp.broadcast_to(bif, (*stack, 2 * H)),
                             st + (None,))
    b.ones("head_norm", (*stack, hd), st + (None,))
    b.linear("w_down", di, d, ("ssm_inner", "fsdp"), stack,
             scale=0.02 / max(1, cfg.n_layers) ** 0.5)


def _causal_conv(u: jax.Array, w: jax.Array,
                 prev: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq. u: (B,S,D); w: (K,D).
    prev: (B,K-1,D) history for decode; returns (out, new history)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], K - 1, u.shape[-1]), dtype=u.dtype)
    full = jnp.concatenate([prev, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
              for i in range(K))
    return out, full[:, -(K - 1):]


def _mlstm_qkvif(p: Dict, cfg: ModelConfig, x: jax.Array, conv_hist=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    di, hd = _inner(cfg)
    u = apply_linear(p["w_up"], x)
    z = apply_linear(p["w_gate"], x)
    c, hist = _causal_conv(u, p["conv"], conv_hist)
    c = jax.nn.silu(c)
    q = apply_linear(p["wq"], c).reshape(B, S, H, hd)
    k = apply_linear(p["wk"], c).reshape(B, S, H, hd) * (hd ** -0.5)
    v = u.reshape(B, S, H, hd)
    gif = (apply_linear(p["w_if"], c)
           + p["gate_bias"]["b_if"].astype(c.dtype)).astype(jnp.float32)
    li = gif[..., :H]                       # raw input gate (exp)
    lf = jax.nn.log_sigmoid(gif[..., H:])   # sigmoid forget gate, log space
    return q, k, v, li, lf, z, hist


def apply_mlstm(p: Dict, cfg: ModelConfig, x: jax.Array,
                *, chunk: int = 128, return_cache: bool = False):
    B, S, _ = x.shape
    di, hd = _inner(cfg)
    q, k, v, li, lf, z, hist = _mlstm_qkvif(p, cfg, x)
    y, st = lscan.chunked_scan(q, k, v, lf, li, chunk=chunk, normalize=True)
    y = head_rms_norm(p["head_norm"], y, cfg.norm_eps)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = constrain(y, "batch", None, "ssm_inner")
    out = apply_linear(p["w_down"], y)
    if return_cache:
        return out, {"state": st, "conv": hist}
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, hd = _inner(cfg)
    return {
        "state": lscan.init_state(batch, cfg.n_heads, hd, hd),
        "conv": jnp.zeros((batch, 3, di), dtype=dtype),
    }


def decode_mlstm(p: Dict, cfg: ModelConfig, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    """x: (B,1,D) single step."""
    B = x.shape[0]
    di, hd = _inner(cfg)
    q, k, v, li, lf, z, hist = _mlstm_qkvif(p, cfg, x, cache["conv"])
    y, st = lscan.step_scan(q[:, 0], k[:, 0], v[:, 0], lf[:, 0], li[:, 0],
                            cache["state"], normalize=True)
    y = head_rms_norm(p["head_norm"], y, cfg.norm_eps)
    y = y.reshape(B, 1, di) * jax.nn.silu(z)
    return apply_linear(p["w_down"], y), {"state": st, "conv": hist}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(b: Builder, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> None:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    st = (None,) * len(stack)
    b.linear("w_in", d, 4 * d, ("fsdp", "ssm_inner"), stack)   # z,i,f,o pre-acts
    # block-diagonal recurrence: (H, hd, hd) per gate
    for g in ("rz", "ri", "rf", "ro"):
        b.normal(g, (*stack, H, hd, hd), (*st, None, None, None),
                 scale=1.0 / hd ** 0.5)
    bias = jnp.concatenate([jnp.zeros(2 * d), 3.0 * jnp.ones(d),
                            jnp.zeros(d)])
    b.sub("bias").const("b", jnp.broadcast_to(bias, (*stack, 4 * d)),
                        st + (None,))
    b.ones("head_norm", (*stack, hd), st + (None,))
    dff = int(4 * d // 3)
    b.linear("ff_gate", d, dff, ("fsdp", "mlp"), stack)
    b.linear("ff_up", d, dff, ("fsdp", "mlp"), stack)
    b.linear("ff_down", dff, d, ("mlp", "fsdp"), stack,
             scale=0.02 / max(1, cfg.n_layers) ** 0.5)


def _slstm_cell(p: Dict, cfg: ModelConfig, pre: jax.Array,
                state: Dict) -> Tuple[jax.Array, Dict]:
    """One step. pre: (B, 4d) input pre-activations (before recurrence).
    state: c,n,h (B,H,hd), m (B,H)."""
    B = pre.shape[0]
    H = cfg.n_heads
    hd = cfg.d_model // H
    h = state["h"]                                        # (B,H,hd)
    rec = [jnp.einsum("bhd,hde->bhe", h, p[g].astype(h.dtype))
           for g in ("rz", "ri", "rf", "ro")]
    parts = pre.reshape(B, 4, H, hd)
    zt = jnp.tanh(parts[:, 0] + rec[0])
    it = (parts[:, 1] + rec[1]).astype(jnp.float32)       # log input gate
    ft = (parts[:, 2] + rec[2]).astype(jnp.float32)       # log forget gate
    ot = jax.nn.sigmoid(parts[:, 3] + rec[3])
    # stabilized exponential gating, per scalar memory cell
    m_new = jnp.maximum(ft + state["m"], it)
    i_g = jnp.exp(it - m_new)
    f_g = jnp.exp(ft + state["m"] - m_new)
    c = f_g * state["c"] + i_g * zt.astype(jnp.float32)
    n = f_g * state["n"] + i_g
    h_new = (ot * (c / jnp.maximum(n, 1e-6)).astype(ot.dtype))
    return h_new, {"c": c, "n": n, "h": h_new, "m": m_new}


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    H = cfg.n_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), dtype=jnp.float32)
    return {"c": z, "n": z, "h": z.astype(dtype), "m": z}


def _slstm_ffn(p: Dict, x: jax.Array) -> jax.Array:
    g = jax.nn.gelu(apply_linear(p["ff_gate"], x))
    h = g * apply_linear(p["ff_up"], x)
    h = constrain(h, "batch", None, "mlp")
    return apply_linear(p["ff_down"], h)


def apply_slstm(p: Dict, cfg: ModelConfig, x: jax.Array,
                *, return_cache: bool = False):
    """Full-sequence sLSTM (sequential lax.scan over time)."""
    B, S, d = x.shape
    H = cfg.n_heads
    pre = apply_linear(p["w_in"], x) + p["bias"]["b"].astype(x.dtype)

    def step(state, pre_t):
        h, st = _slstm_cell(p, cfg, pre_t, state)
        return st, h

    st0 = init_slstm_cache(cfg, B, x.dtype)
    final, hs = jax.lax.scan(step, st0, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)                             # (B,S,H,hd)
    y = head_rms_norm(p["head_norm"], y, cfg.norm_eps).reshape(B, S, d)
    out = _slstm_ffn(p, y)
    if return_cache:
        return out, final
    return out


def decode_slstm(p: Dict, cfg: ModelConfig, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    B, _, d = x.shape
    pre = apply_linear(p["w_in"], x[:, 0]) + p["bias"]["b"].astype(x.dtype)
    h, st = _slstm_cell(p, cfg, pre, cache)
    y = head_rms_norm(p["head_norm"], h, cfg.norm_eps).reshape(B, 1, d)
    return _slstm_ffn(p, y), st
