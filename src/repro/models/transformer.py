"""Model assembly: embeddings, kind-run layer stacks (lax.scan), final norm,
LM head; full-sequence forward (train / prefill), cached decode step, and
encoder–decoder wiring.

A model is a sequence of layer *runs* — consecutive layers of the same kind
(see ``ModelConfig.layer_kinds``). Each run's parameters are stacked along a
leading axis and executed with ``lax.scan`` (small HLO, fast compile, remat
per block). A run's parameter tree may instead be a *list* of per-layer
trees — that is the deploy form of a D-Rank-compressed model whose per-layer
ranks differ — in which case the run executes as an unrolled Python loop.

Batch dictionary convention (everything optional except one input):
  tokens      (B, S) int32       — token ids (decoder side for enc-dec)
  embeds      (B, S, D) float    — precomputed frontend embeddings (vlm/audio
                                   stub); replaces token embedding
  positions   (B, S) or (3, B, S) int32 — rope / m-rope position ids
  enc_embeds  (B, T, D) float    — encoder input (audio stub)
  labels      (B, S) int32       — next-token targets (loss)
  loss_mask   (B, S) float       — optional per-token weights
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import constrain
from repro.models import mamba, rotary, ssm
from repro.models.attention import (attend_decode, attend_full,
                                    attend_prefill, attend_prefill_ext,
                                    init_attention, init_kv_cache)
from repro.models.mlp import apply_mlp, apply_moe, init_mlp, init_moe
from repro.models.params import (Builder, Params, apply_linear, rms_norm,
                                 softcap)

Aux = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(b: Builder, cfg: ModelConfig, kind: str, n: int,
                cross: bool = False) -> None:
    """One run of `n` layers of `kind` (stacked along leading dim)."""
    stack = (n,)
    b.rmsnorm("ln1", cfg.d_model, stack)
    if kind in ("attn", "swa", "hymba", "hymba_g"):
        init_attention(b.sub("attn"), cfg, stack)
    if kind in ("hymba", "hymba_g"):
        mamba.init_ssm(b.sub("ssm"), cfg, stack)
        mamba.init_hymba_combine(b, cfg, stack)
    if kind == "mlstm":
        ssm.init_mlstm(b.sub("mlstm"), cfg, stack)
    if kind == "slstm":
        ssm.init_slstm(b.sub("slstm"), cfg, stack)
    if cross:
        b.rmsnorm("ln_cross", cfg.d_model, stack)
        init_attention(b.sub("cross"), cfg, stack, cross=True)
    # FFN (attention-ish kinds only; ssm kinds carry their own projections)
    if kind in ("attn", "swa", "hymba", "hymba_g"):
        b.rmsnorm("ln2", cfg.d_model, stack)
        if cfg.moe.num_experts:
            init_moe(b, cfg, stack)
        elif cfg.d_ff:
            init_mlp(b.sub("mlp"), cfg, cfg.d_ff, stack)


def init_model(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Params]:
    """Returns (params, specs) — parallel pytrees."""
    b = Builder(key, param_dtype=jnp.dtype(cfg.param_dtype))
    b.normal("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
             scale=1.0 / cfg.d_model ** 0.5)
    dec = b.sub("decoder")
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        _init_block(dec.sub(f"run{r}"), cfg, kind, n,
                    cross=cfg.is_encoder_decoder)
    b.rmsnorm("final_norm", cfg.d_model)
    if not cfg.tie_embeddings:
        b.linear("lm_head", cfg.d_model, cfg.vocab_size, ("embed", "vocab"))
    if cfg.is_encoder_decoder:
        enc = b.sub("encoder")
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        for r, (kind, n) in enumerate(enc_cfg.layer_runs()):
            _init_block(enc.sub(f"run{r}"), enc_cfg, kind, n)
        enc.rmsnorm("enc_norm", cfg.d_model)
    return b.params, b.specs


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params)
               if hasattr(x, "size"))


# ---------------------------------------------------------------------------
# Rope angles per kind
# ---------------------------------------------------------------------------
def _angles_for(cfg: ModelConfig, kind: str,
                positions: Optional[jax.Array]) -> Optional[jax.Array]:
    if cfg.rope_kind == "none" or positions is None:
        return None
    local = kind in ("swa", "hymba") and cfg.rope_theta_local > 0
    theta = cfg.rope_theta_local if local else cfg.rope_theta
    if cfg.rope_kind == "mrope":
        return rotary.mrope_angles(positions, cfg.head_dim, theta,
                                   cfg.mrope_sections)
    return rotary.rope_angles(positions, cfg.head_dim, theta)


def _kind_window(cfg: ModelConfig, kind: str) -> int:
    if kind in ("swa", "hymba"):
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Full-sequence block application (train / eval)
# ---------------------------------------------------------------------------
def _block_fwd(kind: str, cfg: ModelConfig, p: Params, x: jax.Array,
               angles: Optional[jax.Array], enc_out: Optional[jax.Array],
               causal: bool) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux)."""
    aux = jnp.zeros((), dtype=jnp.float32)
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    win = _kind_window(cfg, kind)
    if kind in ("attn", "swa"):
        x = x + attend_full(p["attn"], cfg, h, angles, causal=causal,
                            window=win)
    elif kind in ("hymba", "hymba_g"):
        a = attend_full(p["attn"], cfg, h, angles, causal=causal, window=win)
        s = mamba.apply_ssm(p["ssm"], cfg, h)
        x = x + mamba.hymba_combine(p, cfg, a, s)
    elif kind == "mlstm":
        x = x + ssm.apply_mlstm(p["mlstm"], cfg, h)
    elif kind == "slstm":
        x = x + ssm.apply_slstm(p["slstm"], cfg, h)
    if "ln_cross" in p and enc_out is not None:
        h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attend_full(p["cross"], cfg, h, None, kv=(enc_out, enc_out))
    if "ln2" in p:
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out, aux = apply_moe(p, cfg, h)
            x = x + out
        elif "mlp" in p:
            x = x + apply_mlp(p["mlp"], cfg, h)
    return x, aux


def _run_layers(run_p: Any, cfg: ModelConfig, x: jax.Array, body) -> \
        Tuple[jax.Array, jax.Array]:
    """Apply a run. `body(p_layer, x) -> (x, aux)`. Handles the three param
    layouts: list (unrolled, compressed deploy), stacked+scan, stacked+index.
    """
    if isinstance(run_p, list):
        aux = jnp.zeros((), dtype=jnp.float32)
        for pl in run_p:
            x, a = body(pl, x)
            aux = aux + a
        return x, aux
    n = jax.tree.leaves(run_p)[0].shape[0]
    if not cfg.scan_layers:
        aux = jnp.zeros((), dtype=jnp.float32)
        for i in range(n):
            pl = jax.tree.map(lambda a: a[i], run_p)
            x, a = body(pl, x)
            aux = aux + a
        return x, aux

    def scan_body(carry, pl):
        x, aux = carry
        x, a = body(pl, x)
        return (x, aux + a), None

    wrapped = scan_body
    if cfg.remat != "none":
        # "block": save only layer boundaries, recompute the block in the
        # backward pass; "dots": additionally keep matmul outputs (a §Perf
        # memory/compute trade-off knob).
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        wrapped = jax.checkpoint(scan_body, policy=policy,
                                 prevent_cse=False)
    (x, aux), _ = jax.lax.scan(wrapped, (x, jnp.zeros((), jnp.float32)),
                               run_p)
    return x, aux


def _stack_forward(stack_p: Params, cfg: ModelConfig, x: jax.Array,
                   kinds_runs, positions, enc_out, causal) -> \
        Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), dtype=jnp.float32)
    for r, (kind, n) in enumerate(kinds_runs):
        angles = _angles_for(cfg, kind, positions)
        body = functools.partial(_block_fwd, kind, cfg, angles=angles,
                                 enc_out=enc_out, causal=causal)
        bodyf = lambda pl, xx: body(pl, xx)
        x, a = _run_layers(stack_p[f"run{r}"], cfg, x, bodyf)
        x = constrain(x, "batch", "seq", None)
        aux = aux + a
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def embed_tokens(params: Params, cfg: ModelConfig,
                 tokens: jax.Array) -> jax.Array:
    emb = params["embed"].astype(jnp.dtype(cfg.dtype))
    x = jnp.take(emb, tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype=x.dtype)
    return x


def lm_logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = apply_linear(params["lm_head"], x)
    logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "batch", "seq", "vocab")


def _default_positions(cfg: ModelConfig, batch: Dict) -> Optional[jax.Array]:
    if cfg.rope_kind == "none":
        return None
    if "positions" in batch:
        return batch["positions"]
    src = batch.get("tokens", batch.get("embeds"))
    B, S = src.shape[0], src.shape[1]
    return rotary.make_positions(B, S, cfg.rope_kind)


def encode(params: Params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    """Encoder stack (enc-dec models). Input: enc_embeds (audio stub) or
    enc_tokens."""
    if "enc_embeds" in batch:
        x = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, cfg, batch["enc_tokens"])
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    x = x + rotary.sinusoidal_embed(pos, cfg.d_model).astype(x.dtype)
    enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers, sliding_window=0,
                          local_global_pattern=(0, 0))
    x, _ = _stack_forward(params["encoder"], enc_cfg, x,
                          enc_cfg.layer_runs(), None, None, causal=False)
    return rms_norm(params["encoder"]["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward (train / eval, full sequence)
# ---------------------------------------------------------------------------
def forward(params: Params, cfg: ModelConfig,
            batch: Dict) -> Tuple[jax.Array, Aux]:
    """Full-sequence forward. Returns (logits (B,S,V), aux)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encode(params, cfg, batch)
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, cfg, batch["tokens"])
    if cfg.is_encoder_decoder:
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = x + rotary.sinusoidal_embed(pos, cfg.d_model).astype(x.dtype)
    x = constrain(x, "batch", "seq", None)
    positions = _default_positions(cfg, batch)
    x, moe_aux = _stack_forward(params["decoder"], cfg, x, cfg.layer_runs(),
                                positions, enc_out, causal=True)
    logits = lm_logits(params, cfg, x)
    return logits, {"moe_aux": moe_aux}


def lm_loss(params: Params, cfg: ModelConfig,
            batch: Dict) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE. If batch has explicit `labels`, logits align 1:1 with
    them; otherwise labels are tokens shifted left by one."""
    logits, aux = forward(params, cfg, batch)
    if "labels" in batch:
        labels = batch["labels"]
    else:
        labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)),
                         constant_values=-1)
    mask = (labels >= 0).astype(jnp.float32)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"]
    labels_c = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels_c[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    acc = (jnp.argmax(lf, -1) == labels_c).astype(jnp.float32) * mask
    metrics = {
        "loss": loss,
        "ppl_log": loss,                      # exp() applied host-side
        "accuracy": acc.sum() / denom,
        "tokens": mask.sum(),
    }
    if cfg.moe.num_experts:
        loss = loss + cfg.moe.aux_loss_weight * aux["moe_aux"] / max(
            1, cfg.n_layers)
        metrics["moe_aux"] = aux["moe_aux"]
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (single step with caches)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict:
    """Cache pytree: per-run stacked caches + per-sequence positions."""
    dtype = jnp.dtype(cfg.dtype)
    runs: Dict[str, Any] = {}
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        win = _kind_window(cfg, kind)
        entry: Dict[str, Any] = {}
        if kind in ("attn", "swa", "hymba", "hymba_g"):
            kv = [init_kv_cache(cfg, batch, max_len, win, dtype)
                  for _ in range(n)]
            entry["kv"] = jax.tree.map(lambda *a: jnp.stack(a), *kv)
        if kind in ("hymba", "hymba_g"):
            ss = [mamba.init_ssm_cache(cfg, batch, dtype) for _ in range(n)]
            entry["ssm"] = jax.tree.map(lambda *a: jnp.stack(a), *ss)
        if kind == "mlstm":
            ms = [ssm.init_mlstm_cache(cfg, batch, dtype) for _ in range(n)]
            entry["mlstm"] = jax.tree.map(lambda *a: jnp.stack(a), *ms)
        if kind == "slstm":
            sl = [ssm.init_slstm_cache(cfg, batch, dtype) for _ in range(n)]
            entry["slstm"] = jax.tree.map(lambda *a: jnp.stack(a), *sl)
        if cfg.is_encoder_decoder:
            entry["cross_kv"] = {
                "k": jnp.zeros((n, batch, enc_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype=dtype),
                "v": jnp.zeros((n, batch, enc_len, cfg.n_kv_heads,
                                cfg.head_dim), dtype=dtype),
            }
        runs[f"run{r}"] = entry
    # pos = -1 marks a dead slot (never admitted / purged): decode leaves it
    # parked at -1 and emits exact-zero attention for it. Admission scatter
    # overwrites pos with the prefilled length.
    return {"runs": runs, "pos": jnp.full((batch,), -1, dtype=jnp.int32)}


def init_cache_paged(cfg: ModelConfig, batch: int, blocks: int,
                     block_len: int) -> Dict:
    """Paged cache pytree: one flat KV block arena per run instead of the
    per-slot (batch, max_len) pool. k/v leaves are (n, blocks, block_len,
    KV, hd); physical block 0 is reserved as the never-allocated null block
    (the sentinel target for dead table entries). Logical-to-physical
    mapping lives OUTSIDE the pytree in the engine's (batch, NB) block
    table. Pure-attention stacks only — recurrent kinds have no paged
    layout (and windowed kinds keep the ring cache)."""
    dtype = jnp.dtype(cfg.dtype)
    assert not cfg.is_encoder_decoder, "paged cache: decoder-only"
    runs: Dict[str, Any] = {}
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        assert kind == "attn", (
            f"paged cache supports pure-attention stacks only, got {kind}")
        runs[f"run{r}"] = {"kv": {
            "k": jnp.zeros((n, blocks, block_len, cfg.n_kv_heads,
                            cfg.head_dim), dtype=dtype),
            "v": jnp.zeros((n, blocks, block_len, cfg.n_kv_heads,
                            cfg.head_dim), dtype=dtype),
        }}
    return {"runs": runs, "pos": jnp.full((batch,), -1, dtype=jnp.int32)}


def _block_decode(kind: str, cfg: ModelConfig, p: Params, cache: Dict,
                  x: jax.Array, pos: jax.Array,
                  angles: Optional[jax.Array],
                  table: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    new_cache: Dict[str, Any] = {}
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    win = _kind_window(cfg, kind)
    if kind in ("attn", "swa"):
        out, kv = attend_decode(p["attn"], cfg, h, pos, cache["kv"], angles,
                                window=win, table=table)
        x = x + out
        new_cache["kv"] = kv
    elif kind in ("hymba", "hymba_g"):
        a, kv = attend_decode(p["attn"], cfg, h, pos, cache["kv"], angles,
                              window=win, table=table)
        s, sst = mamba.decode_ssm(p["ssm"], cfg, h, cache["ssm"])
        x = x + mamba.hymba_combine(p, cfg, a, s)
        new_cache["kv"], new_cache["ssm"] = kv, sst
    elif kind == "mlstm":
        out, mst = ssm.decode_mlstm(p["mlstm"], cfg, h, cache["mlstm"])
        x = x + out
        new_cache["mlstm"] = mst
    elif kind == "slstm":
        out, sst = ssm.decode_slstm(p["slstm"], cfg, h, cache["slstm"])
        x = x + out
        new_cache["slstm"] = sst
    if "ln_cross" in p and "cross_kv" in cache:
        h = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        ckv = (cache["cross_kv"]["k"], cache["cross_kv"]["v"])
        out, _ = attend_decode(p["cross"], cfg, h, pos, {}, None,
                               cross_kv=ckv)
        x = x + out
        new_cache["cross_kv"] = cache["cross_kv"]
    if "ln2" in p:
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out, _ = apply_moe(p, cfg, h)
            x = x + out
        elif "mlp" in p:
            x = x + apply_mlp(p["mlp"], cfg, h)
    return x, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Dict,
                tokens_or_embeds: jax.Array,
                positions: Optional[jax.Array] = None,
                table: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Dict]:
    """One new token per sequence. tokens (B,1) int or embeds (B,1,D).
    With `table` (B, NB) int32 the cache is a paged arena (see
    init_cache_paged) and every KV read/write indirects through it.
    Dead slots (pos = -1) neither advance nor write: their logits row is
    whatever the dead residual stream produces and is ignored upstream.
    Returns (logits (B,1,V), new cache)."""
    pos = cache["pos"]
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = embed_tokens(params, cfg, tokens_or_embeds)
    else:
        x = tokens_or_embeds.astype(jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        x = x + rotary.sinusoidal_embed(pos[:, None], cfg.d_model
                                        ).astype(x.dtype)
    new_runs: Dict[str, Any] = {}
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        if positions is not None:
            rp = positions
        elif cfg.rope_kind == "mrope":
            rp = jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
        else:
            rp = pos[:, None]
        angles = _angles_for(cfg, kind, rp)
        run_p = params["decoder"][f"run{r}"]
        run_c = cache["runs"][f"run{r}"]

        if isinstance(run_p, list):
            ncs = []
            for i, pl in enumerate(run_p):
                cl = jax.tree.map(lambda a: a[i], run_c)
                x, nc = _block_decode(kind, cfg, pl, cl, x, pos, angles,
                                      table)
                ncs.append(nc)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        elif not cfg.scan_layers:
            ncs = []
            for i in range(n):
                pl = jax.tree.map(lambda a: a[i], run_p)
                cl = jax.tree.map(lambda a: a[i], run_c)
                x, nc = _block_decode(kind, cfg, pl, cl, x, pos, angles,
                                      table)
                ncs.append(nc)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        else:
            def body(xx, pc):
                pl, cl = pc
                xx, nc = _block_decode(kind, cfg, pl, cl, xx, pos, angles,
                                       table)
                return xx, nc
            x, nc = jax.lax.scan(body, x, (run_p, run_c))
            new_runs[f"run{r}"] = nc
    logits = lm_logits(params, cfg, x)
    # dead slots (pos = -1) stay dead; live slots advance
    return logits, {"runs": new_runs,
                    "pos": jnp.where(pos >= 0, pos + 1, pos)}


# ---------------------------------------------------------------------------
# Prefill (full sequence -> cache)
# ---------------------------------------------------------------------------
def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def _block_prefill(kind: str, cfg: ModelConfig, p: Params, x: jax.Array,
                   angles, max_len: int, enc_out,
                   lengths: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict]:
    cache: Dict[str, Any] = {}
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    win = _kind_window(cfg, kind)
    if kind in ("attn", "swa"):
        out, kv = attend_prefill(p["attn"], cfg, h, angles, causal=True,
                                 window=win, max_len=max_len,
                                 lengths=lengths)
        x = x + out
        cache["kv"] = kv
    elif kind in ("hymba", "hymba_g"):
        a, kv = attend_prefill(p["attn"], cfg, h, angles, causal=True,
                               window=win, max_len=max_len,
                               lengths=lengths)
        s, sst = mamba.apply_ssm(p["ssm"], cfg, h, return_cache=True)
        x = x + mamba.hymba_combine(p, cfg, a, s)
        cache["kv"], cache["ssm"] = kv, sst
    elif kind == "mlstm":
        out, mst = ssm.apply_mlstm(p["mlstm"], cfg, h, return_cache=True)
        x = x + out
        cache["mlstm"] = mst
    elif kind == "slstm":
        out, sst = ssm.apply_slstm(p["slstm"], cfg, h, return_cache=True)
        x = x + out
        cache["slstm"] = sst
    if "ln_cross" in p and enc_out is not None:
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attend_full(p["cross"], cfg, hc, None, kv=(enc_out, enc_out))
        # materialize per-layer cross K/V once for the decode loop
        cache["cross_kv"] = {
            "k": _split_heads(apply_linear(p["cross"]["wk"], enc_out),
                              cfg.n_kv_heads, cfg.head_dim),
            "v": _split_heads(apply_linear(p["cross"]["wv"], enc_out),
                              cfg.n_kv_heads, cfg.head_dim),
        }
    if "ln2" in p:
        h = rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out, _ = apply_moe(p, cfg, h)
            x = x + out
        elif "mlp" in p:
            x = x + apply_mlp(p["mlp"], cfg, h)
    return x, cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict,
            max_len: int) -> Tuple[jax.Array, Dict]:
    """Process the prompt, build the decode cache. Returns
    (logits of the last live position (B, 1, V), cache).

    `batch["lengths"]` (B,) int32, optional: per-row live prompt lengths
    when prompts are right-padded to a common bucket (continuous-batching
    admission). Cache slots past a row's length are zeroed/masked, the
    returned logits are each row's last LIVE position, and cache `pos`
    starts at the per-row length. Recurrent-state kinds (ssm/lstm) carry
    state through padded steps, so callers only pass `lengths` for pure
    attention stacks — see ContinuousBatcher."""
    lengths = batch.get("lengths")
    enc_out = encode(params, cfg, batch) if cfg.is_encoder_decoder else None
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, cfg, batch["tokens"])
    B, S, _ = x.shape
    if cfg.is_encoder_decoder:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = x + rotary.sinusoidal_embed(pos, cfg.d_model).astype(x.dtype)
    x = constrain(x, "batch", "seq", None)
    positions = _default_positions(cfg, batch)

    new_runs: Dict[str, Any] = {}
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        angles = _angles_for(cfg, kind, positions)
        run_p = params["decoder"][f"run{r}"]

        def body(pl, xx):
            return _block_prefill(kind, cfg, pl, xx, angles, max_len,
                                  enc_out, lengths)

        if isinstance(run_p, list):
            caches = []
            for pl in run_p:
                x, c = body(pl, x)
                caches.append(c)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a),
                                               *caches)
        elif not cfg.scan_layers:
            caches = []
            for i in range(n):
                pl = jax.tree.map(lambda a: a[i], run_p)
                x, c = body(pl, x)
                caches.append(c)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a),
                                               *caches)
        else:
            def scan_body(xx, pl):
                return body(pl, xx)
            x, nc = jax.lax.scan(scan_body, x, run_p)
            new_runs[f"run{r}"] = nc
        x = constrain(x, "batch", "seq", None)
    if lengths is None:
        x_last = x[:, -1:]
        pos0 = jnp.full((B,), S, dtype=jnp.int32)
    else:
        x_last = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
        pos0 = lengths.astype(jnp.int32)
    logits = lm_logits(params, cfg, x_last)
    cache = {"runs": new_runs, "pos": pos0}
    return logits, cache


def prefill_ext(params: Params, cfg: ModelConfig, batch: Dict,
                arena: Dict, table: jax.Array) -> Tuple[jax.Array, Dict]:
    """Tail prefill for prefix-reuse admission (paged pool only): process
    the UNSHARED tail of each prompt against a shared prefix already
    resident in the paged arena.

    batch: tokens (B, St) right-padded tail token ids; lengths (B,) int32
    live tail lengths; starts (B,) int32 prefix lengths (tail position i is
    absolute position starts + i). arena: init_cache_paged pytree; table:
    (B, NB) int32 block table (first `starts[b]` positions = the prefix).

    Returns (logits of each row's last live tail position (B, 1, V), tail
    cache) — tail cache leaves are (n, B, St, KV, hd) in slot layout (slot
    s = tail position s), for scatter_paged to write through the table at
    the absolute offsets. Cache `pos` = starts + lengths (total live
    length). Pure-attention stacks only."""
    lengths = batch["lengths"].astype(jnp.int32)
    starts = batch["starts"].astype(jnp.int32)
    x = embed_tokens(params, cfg, batch["tokens"])
    B, S, _ = x.shape
    x = constrain(x, "batch", "seq", None)
    positions = None
    if cfg.rope_kind != "none":
        positions = starts[:, None] + jnp.arange(S)[None, :]

    new_runs: Dict[str, Any] = {}
    for r, (kind, n) in enumerate(cfg.layer_runs()):
        assert kind == "attn", (
            f"prefill_ext supports pure-attention stacks only, got {kind}")
        angles = _angles_for(cfg, kind, positions)
        run_p = params["decoder"][f"run{r}"]
        arena_c = arena["runs"][f"run{r}"]

        def body(pl, cl, xx):
            h = rms_norm(pl["ln1"], xx, cfg.norm_eps)
            out, kv = attend_prefill_ext(pl["attn"], cfg, h, angles,
                                         cl["kv"], table, starts, lengths)
            xx = xx + out
            if "ln2" in pl:
                h = rms_norm(pl["ln2"], xx, cfg.norm_eps)
                if "moe" in pl:
                    out, _ = apply_moe(pl, cfg, h)
                    xx = xx + out
                elif "mlp" in pl:
                    xx = xx + apply_mlp(pl["mlp"], cfg, h)
            return xx, {"kv": kv}

        if isinstance(run_p, list):
            caches = []
            for i, pl in enumerate(run_p):
                cl = jax.tree.map(lambda a: a[i], arena_c)
                x, c = body(pl, cl, x)
                caches.append(c)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a),
                                               *caches)
        elif not cfg.scan_layers:
            caches = []
            for i in range(n):
                pl = jax.tree.map(lambda a: a[i], run_p)
                cl = jax.tree.map(lambda a: a[i], arena_c)
                x, c = body(pl, cl, x)
                caches.append(c)
            new_runs[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a),
                                               *caches)
        else:
            def scan_body(xx, pc):
                pl, cl = pc
                return body(pl, cl, xx)
            x, nc = jax.lax.scan(scan_body, x, (run_p, arena_c))
            new_runs[f"run{r}"] = nc
        x = constrain(x, "batch", "seq", None)
    x_last = jnp.take_along_axis(x, (jnp.maximum(lengths, 1) - 1)
                                 [:, None, None], axis=1)
    logits = lm_logits(params, cfg, x_last)
    return logits, {"runs": new_runs, "pos": starts + lengths}
