"""Parameter construction + the dense/factorized linear runtime.

Params are plain nested dicts of jnp arrays; alongside every params tree we
build a parallel *spec tree* whose leaves are tuples of logical axis names
(see ``repro.dist.sharding``). A linear is either

  dense       {"w": (d_in, d_out) [, "b": (d_out,)]}
  factorized  {"B": (d_in, r), "C": (r, d_out) [, "b": ...]}   # D-Rank deploy form

optionally with a leading stack dim (n_layers_in_run, ...) for scanned
layer stacks. ``apply_linear`` dispatches on the keys, so a compressed
checkpoint drops into the same model code.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, object]
Specs = Dict[str, object]

# Global switch flipped by the launcher on TPU: route factorized matmuls
# through the fused Pallas kernel instead of two jnp matmuls.
_KERNEL_STATE = threading.local()


def set_use_pallas(flag: bool) -> None:
    _KERNEL_STATE.use = flag


def use_pallas() -> bool:
    return getattr(_KERNEL_STATE, "use", False)


# Calibration capture: when enabled, every apply_linear on a param dict
# carrying a "_tag" key reports its input activations to the active capture
# target (repro.core.capture): either the eager host Collector, or a
# StreamingTape recording device-side fp32 Gram partials while a jit'd
# calibration step is being traced.
_CAPTURE = threading.local()


def set_capture(collector) -> None:
    _CAPTURE.collector = collector


def get_capture():
    return getattr(_CAPTURE, "collector", None)


class Builder:
    """Collects (params, specs) pairs; deterministic key splitting."""

    def __init__(self, key: jax.Array, param_dtype=jnp.float32):
        self._key = key
        self._n = 0
        self.param_dtype = param_dtype
        self.params: Params = {}
        self.specs: Specs = {}

    def _next_key(self) -> jax.Array:
        self._n += 1
        return jax.random.fold_in(self._key, self._n)

    def sub(self, name: str) -> "Builder":
        b = Builder.__new__(Builder)
        b._key = jax.random.fold_in(self._key, hash(name) % (2 ** 31))
        b._n = 0
        b.param_dtype = self.param_dtype
        b.params = self.params.setdefault(name, {})
        b.specs = self.specs.setdefault(name, {})
        return b

    def normal(self, name: str, shape: Sequence[int],
               axes: Sequence[Optional[str]], scale: float = 0.02):
        assert len(shape) == len(axes), (name, shape, axes)
        arr = scale * jax.random.normal(self._next_key(), tuple(shape),
                                        dtype=jnp.float32)
        self.params[name] = arr.astype(self.param_dtype)
        self.specs[name] = tuple(axes)

    def zeros(self, name, shape, axes):
        self.params[name] = jnp.zeros(tuple(shape), dtype=self.param_dtype)
        self.specs[name] = tuple(axes)

    def ones(self, name, shape, axes):
        self.params[name] = jnp.ones(tuple(shape), dtype=self.param_dtype)
        self.specs[name] = tuple(axes)

    def const(self, name, value, axes):
        self.params[name] = jnp.asarray(value, dtype=self.param_dtype)
        self.specs[name] = tuple(axes)

    # -- composite helpers --------------------------------------------------
    def linear(self, name: str, d_in: int, d_out: int,
               axes: Tuple[Optional[str], Optional[str]],
               stack: Tuple[int, ...] = (), bias: bool = False,
               scale: Optional[float] = None):
        """Dense linear (the compressor may later replace it by B/C)."""
        sub = self.sub(name)
        s = 0.02 if scale is None else scale
        stack_axes = (None,) * len(stack)
        sub.normal("w", (*stack, d_in, d_out), (*stack_axes, *axes), scale=s)
        if bias:
            sub.zeros("b", (*stack, d_out), (*stack_axes, axes[1]))

    def rmsnorm(self, name: str, dim: int, stack: Tuple[int, ...] = ()):
        self.sub(name).ones("scale", (*stack, dim),
                            ((None,) * len(stack)) + (None,))


# ---------------------------------------------------------------------------
# Apply fns
# ---------------------------------------------------------------------------
def apply_linear(p: Params, x: jax.Array, dtype=None) -> jax.Array:
    """x: (..., d_in) -> (..., d_out); dense or factorized."""
    dtype = dtype or x.dtype
    cap = get_capture()
    if cap is not None and "_tag" in p:
        cap.add(p["_tag"], x)
    if "B" in p:
        b = p["B"].astype(dtype)
        c = p["C"].astype(dtype)
        if use_pallas():
            from repro.kernels import ops as kops
            y = kops.lowrank_matmul(x, b, c)
        else:
            y = (x @ b) @ c
    else:
        y = x @ p["w"].astype(dtype)
    if "lora_A" in p:        # LoRA adapter: y += scale * x A B
        y = y + p["lora_scale"].astype(dtype) * (
            (x @ p["lora_A"].astype(dtype)) @ p["lora_B"].astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def linear_out_dim(p: Params) -> int:
    return (p["C"] if "B" in p else p["w"]).shape[-1]


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def head_rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: normalize over the trailing head_dim."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
