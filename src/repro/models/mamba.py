"""Selective SSM (Mamba-2 / SSD formulation) and the Hymba parallel
attention+SSM block.

TPU adaptation (DESIGN.md §3): the per-channel diagonal recurrence of
Mamba-1 materializes (chunk × chunk × d_inner) decay tensors that blow VMEM;
Mamba-2's SSD form makes the decay a per-head scalar, which maps the whole
layer onto the shared chunked linear-recurrence engine
(``repro.models.linear_scan``) — pure MXU matmuls plus an O(S/chunk) scan.

SSD step (head h):   S_t = exp(Δ_t A_h) S_{t-1} + (Δ_t u_t) ⊗ B_t
                     y_t = S_t C_t + D_h u_t
mapped as q := C_t (state readout), k := B_t, v := Δ_t u_t,
log_f := Δ_t A_h (A_h < 0), log_i := 0, normalize=False.

Hymba block (arXiv:2411.13676): attention and SSM run in *parallel* on the
same normed input; per-branch RMS norm then a learned per-channel convex
combination. Sliding-window attention on most layers, global on
{first, middle, last} (see ModelConfig.layer_kinds).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.dist.sharding import constrain
from repro.models import linear_scan as lscan
from repro.models.params import Builder, apply_linear, rms_norm
from repro.models.ssm import _causal_conv


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.n_heads
    hd = d_inner // heads
    return d_inner, heads, hd


def init_ssm(b: Builder, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> None:
    d = cfg.d_model
    di, H, hd = _dims(cfg)
    N = cfg.ssm_state
    st = (None,) * len(stack)
    b.linear("w_in", d, di, ("fsdp", "ssm_inner"), stack)      # u branch
    b.linear("w_z", d, di, ("fsdp", "ssm_inner"), stack)       # gate branch
    b.normal("conv", (*stack, cfg.ssm_conv, di), (*st, None, "ssm_inner"),
             scale=0.1)
    # selective params from the conv'd branch: B, C (per head, N each), Δ (per head)
    b.linear("w_bc", di, 2 * H * N, ("ssm_inner", None), stack)
    b.linear("w_dt", di, H, ("ssm_inner", None), stack)
    sub = b.sub("ssm_core")
    a_log = jnp.log(jnp.linspace(1.0, 16.0, H))
    sub.const("a_log", jnp.broadcast_to(a_log, (*stack, H)),
              st + (None,))                                    # A_h = -exp(a_log)
    sub.zeros("dt_bias", (*stack, H), st + (None,))
    sub.ones("d_skip", (*stack, H), st + (None,))
    b.ones("head_norm", (*stack, hd), st + (None,))
    b.linear("w_out", di, d, ("ssm_inner", "fsdp"), stack,
             scale=0.02 / max(1, cfg.n_layers) ** 0.5)


def _ssm_inputs(p: Dict, cfg: ModelConfig, x: jax.Array, conv_hist=None):
    """Shared by full-seq and decode paths. x: (B,S,d)."""
    B, S, _ = x.shape
    di, H, hd = _dims(cfg)
    N = cfg.ssm_state
    u = apply_linear(p["w_in"], x)
    z = apply_linear(p["w_z"], x)
    c, hist = _causal_conv(u, p["conv"], conv_hist)
    c = jax.nn.silu(c)
    bc = apply_linear(p["w_bc"], c).reshape(B, S, 2, H, N)
    k = bc[:, :, 0]                                            # B_t (B,S,H,N)
    q = bc[:, :, 1]                                            # C_t
    dt_raw = apply_linear(p["w_dt"], c) + p["ssm_core"]["dt_bias"].astype(c.dtype)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))           # (B,S,H)
    A = -jnp.exp(p["ssm_core"]["a_log"].astype(jnp.float32))   # (H,)
    log_f = dt * A                                             # <= 0
    v = c.reshape(B, S, H, hd) * dt[..., None].astype(c.dtype)  # Δ_t u_t
    return q, k, v, log_f, z, c, hist


def apply_ssm(p: Dict, cfg: ModelConfig, x: jax.Array,
              *, chunk: int = 128, return_cache: bool = False):
    B, S, _ = x.shape
    di, H, hd = _dims(cfg)
    q, k, v, log_f, z, c, hist = _ssm_inputs(p, cfg, x)
    li = jnp.zeros_like(log_f)
    y, st = lscan.chunked_scan(q, k, v, log_f, li, chunk=chunk,
                               normalize=False)
    d_skip = p["ssm_core"]["d_skip"].astype(y.dtype)           # (H,)
    y = y + c.reshape(B, S, H, hd) * d_skip[:, None]
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    y = constrain(y, "batch", None, "ssm_inner")
    out = apply_linear(p["w_out"], y)
    if return_cache:
        return out, {"state": st, "conv": hist}
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, H, hd = _dims(cfg)
    return {
        "state": lscan.init_state(batch, H, cfg.ssm_state, hd),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype=dtype),
    }


def decode_ssm(p: Dict, cfg: ModelConfig, x: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    di, H, hd = _dims(cfg)
    q, k, v, log_f, z, c, hist = _ssm_inputs(p, cfg, x, cache["conv"])
    li = jnp.zeros_like(log_f)
    y, st = lscan.step_scan(q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], li[:, 0],
                            cache["state"], normalize=False)
    y = y + c[:, 0].reshape(B, H, hd) * p["ssm_core"]["d_skip"].astype(
        y.dtype)[:, None]
    y = y.reshape(B, 1, di) * jax.nn.silu(z)
    return apply_linear(p["w_out"], y), {"state": st, "conv": hist}


# ---------------------------------------------------------------------------
# Hymba parallel-head combine
# ---------------------------------------------------------------------------
def init_hymba_combine(b: Builder, cfg: ModelConfig,
                       stack: Tuple[int, ...] = ()) -> None:
    st = (None,) * len(stack)
    sub = b.sub("combine")
    sub.ones("g_attn", (*stack, cfg.d_model), st + (None,))
    sub.ones("g_ssm", (*stack, cfg.d_model), st + (None,))
    sub.ones("norm_attn", (*stack, cfg.d_model), st + (None,))
    sub.ones("norm_ssm", (*stack, cfg.d_model), st + (None,))


def hymba_combine(p: Dict, cfg: ModelConfig, attn_out: jax.Array,
                  ssm_out: jax.Array) -> jax.Array:
    c = p["combine"]
    a = rms_norm({"scale": c["norm_attn"]}, attn_out, cfg.norm_eps)
    s = rms_norm({"scale": c["norm_ssm"]}, ssm_out, cfg.norm_eps)
    return 0.5 * (c["g_attn"].astype(a.dtype) * a
                  + c["g_ssm"].astype(s.dtype) * s)
