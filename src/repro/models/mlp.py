"""Dense FFN (SwiGLU / GeLU) and Mixture-of-Experts with expert parallelism.

MoE design (TPU-native, see DESIGN.md §3):
  * experts are sharded over the ``model`` mesh axis (EP); the expert count
    is padded to a multiple of the EP degree and the router masks padding.
  * token routing is capacity-based (GShard-style drops) but dispatched by
    *scatter into fixed-capacity buffers* + ``lax.all_to_all``, not the
    O(T·E·C) one-hot einsum — that einsum is infeasible at 1M-token batches.
  * a second-level per-expert dispatch turns the received tokens into an
    (E_local, C2, D) batched-GEMM operand, so expert FLOPs are exact
    (no masked redundant compute).
  * single-device path (tests / no mesh) is the same code with EP=1 and the
    all_to_all skipped.

Everything is differentiable (scatters/gathers/all_to_all have transposes),
so the same layer serves train and serve.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, MoEConfig
from repro.dist.sharding import constrain, current_mesh, shard_map
from repro.models.params import Builder, apply_linear, get_capture


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------
def init_mlp(b: Builder, cfg: ModelConfig, d_ff: int,
             stack: Tuple[int, ...] = ()) -> None:
    out_scale = 0.02 / max(1, cfg.n_layers) ** 0.5
    if cfg.mlp_kind in ("swiglu", "geglu"):
        b.linear("w_gate", cfg.d_model, d_ff, ("fsdp", "mlp"), stack)
        b.linear("w_up", cfg.d_model, d_ff, ("fsdp", "mlp"), stack)
        b.linear("w_down", d_ff, cfg.d_model, ("mlp", "fsdp"), stack,
                 scale=out_scale)
    else:  # gelu
        b.linear("w_up", cfg.d_model, d_ff, ("fsdp", "mlp"), stack)
        b.linear("w_down", d_ff, cfg.d_model, ("mlp", "fsdp"), stack,
                 scale=out_scale)


def apply_mlp(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if "w_gate" in p:
        act = jax.nn.gelu if cfg.mlp_kind == "geglu" else jax.nn.silu
        h = act(apply_linear(p["w_gate"], x)) * apply_linear(p["w_up"], x)
    else:
        h = jax.nn.gelu(apply_linear(p["w_up"], x))
    h = constrain(h, "batch", None, "mlp")
    return apply_linear(p["w_down"], h)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def init_moe(b: Builder, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> None:
    m = cfg.moe
    E = m.padded_experts
    sub = b.sub("moe")
    sub.linear("router", cfg.d_model, E, ("fsdp", None), stack)
    st_axes = (None,) * len(stack)
    # expert weights: (E, d, f) stacked — E shards over model (EP)
    sub.normal("w_gate", (*stack, E, cfg.d_model, m.d_expert),
               (*st_axes, "experts", "fsdp", None))
    sub.normal("w_up", (*stack, E, cfg.d_model, m.d_expert),
               (*st_axes, "experts", "fsdp", None))
    sub.normal("w_down", (*stack, E, m.d_expert, cfg.d_model),
               (*st_axes, "experts", None, "fsdp"),
               scale=0.02 / max(1, cfg.n_layers) ** 0.5)
    if m.num_shared:
        shared = b.sub("moe_shared")
        d_sh = m.d_shared * m.num_shared
        shared.linear("w_gate", cfg.d_model, d_sh, ("fsdp", "mlp"), stack)
        shared.linear("w_up", cfg.d_model, d_sh, ("fsdp", "mlp"), stack)
        shared.linear("w_down", d_sh, cfg.d_model, ("mlp", "fsdp"), stack)
        shared.linear("shared_gate", cfg.d_model, 1, ("fsdp", None), stack)


def _dispatch_to_buffers(x: jax.Array, dest: jax.Array, n_dest: int,
                         capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Scatter rows of x (N, D) into (n_dest, capacity, D) buffers.

    dest: (N,) int destination id per row (>= n_dest means 'drop').
    Returns (buffers, slot_of_row (N,), kept_mask (N,)). Rows beyond a
    destination's capacity are dropped (GShard capacity semantics).
    """
    N, D = x.shape
    onehot = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)      # (N, n_dest)
    pos_in_dest = (jnp.cumsum(onehot, axis=0) - onehot)          # rank within dest
    slot = jnp.sum(pos_in_dest * onehot, axis=1)                 # (N,)
    kept = (slot < capacity) & (dest < n_dest)
    flat_idx = jnp.where(kept, dest * capacity + slot, n_dest * capacity)
    buf = jnp.zeros((n_dest * capacity + 1, D), dtype=x.dtype)
    buf = buf.at[flat_idx].set(jnp.where(kept[:, None], x, 0))
    return buf[:-1].reshape(n_dest, capacity, D), slot, kept


def _undispatch(buffers: jax.Array, dest: jax.Array, slot: jax.Array,
                kept: jax.Array) -> jax.Array:
    """Gather rows back: inverse of _dispatch_to_buffers."""
    n_dest, capacity, D = buffers.shape
    flat = buffers.reshape(n_dest * capacity, D)
    idx = jnp.clip(dest * capacity + slot, 0, n_dest * capacity - 1)
    rows = flat[idx]
    return jnp.where(kept[:, None], rows, 0)


def _expert_mm(w, xs: jax.Array) -> jax.Array:
    """Per-expert batched matmul. w: (E, D, F) dense array OR factorized
    {"B": (E, D, R), "C": (E, R, F)} (D-Rank deploy form, rank-padded)."""
    if isinstance(w, dict):
        t = jnp.einsum("ecd,edr->ecr", xs, w["B"].astype(xs.dtype))
        return jnp.einsum("ecr,erf->ecf", t, w["C"].astype(xs.dtype))
    return jnp.einsum("ecd,edf->ecf", xs, w.astype(xs.dtype))


def _expert_ffn(w_gate, w_up, w_down, xs: jax.Array,
                tag: Optional[str] = None) -> jax.Array:
    """xs: (E_local, C2, D); weights (E_local, D, F)/(E_local, F, D)."""
    cap = get_capture()
    if cap is not None and tag:
        cap.add_expert_batch(tag + "/in", xs)
    h = jax.nn.silu(_expert_mm(w_gate, xs)) * _expert_mm(w_up, xs)
    if cap is not None and tag:
        cap.add_expert_batch(tag + "/mid", h)
    return _expert_mm(w_down, h)


def _moe_local(p: Dict, m: MoEConfig, x: jax.Array, ep: int,
               axis_name: Optional[str],
               tag: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE body. x: (T, D) local tokens; experts sharded over
    `axis_name` into `ep` shards (E_local each). Returns (out, aux_loss)."""
    T, D = x.shape
    E = m.padded_experts
    e_local = E // ep
    k = m.top_k

    logits = x @ p["router"].astype(x.dtype)                  # (T, E)
    if m.num_experts < E:                                     # mask padding
        pad = jnp.arange(E) >= m.num_experts
        logits = jnp.where(pad[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # load-balancing aux loss (Switch-style) over real experts
    me = jnp.mean(probs[:, :m.num_experts], axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(expert_ids, E).sum(1))[:, :m.num_experts], axis=0)
    aux = m.num_experts * jnp.sum(me * ce)

    # ---- first-level dispatch: shard-to-shard all_to_all -----------------
    xs = jnp.repeat(x, k, axis=0)                             # (T*k, D)
    eids = expert_ids.reshape(-1)                             # (T*k,)
    gates = gate_vals.reshape(-1).astype(x.dtype)
    cap1 = int(math.ceil(T * k / ep * m.capacity_factor))
    cap1 = max(8, -(-cap1 // 8) * 8)
    dest_shard = eids // e_local
    send, slot1, kept1 = _dispatch_to_buffers(xs, dest_shard, ep, cap1)
    send_meta = jnp.stack([                                    # ride along
        (eids % e_local).astype(x.dtype), jnp.zeros_like(gates)], axis=-1)
    meta_buf, _, _ = _dispatch_to_buffers(send_meta, dest_shard, ep, cap1)
    if axis_name is not None and ep > 1:
        recv = jax.lax.all_to_all(send, axis_name, 0, 0, tiled=False)
        meta = jax.lax.all_to_all(meta_buf, axis_name, 0, 0, tiled=False)
    else:
        recv, meta = send, meta_buf
    recv = recv.reshape(ep * cap1, D)
    local_eid = meta.reshape(ep * cap1, 2)[:, 0].astype(jnp.int32)

    # ---- second-level dispatch: per-local-expert batched GEMM ------------
    cap2 = int(math.ceil(ep * cap1 / e_local * m.capacity_factor))
    cap2 = max(8, -(-cap2 // 8) * 8)
    ebuf, slot2, kept2 = _dispatch_to_buffers(recv, local_eid, e_local, cap2)
    eout = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], ebuf, tag=tag)
    back = _undispatch(eout, local_eid, slot2, kept2)          # (ep*cap1, D)

    # ---- return trip ------------------------------------------------------
    back = back.reshape(ep, cap1, D)
    if axis_name is not None and ep > 1:
        back = jax.lax.all_to_all(back, axis_name, 0, 0, tiled=False)
    rows = _undispatch(back, dest_shard, slot1, kept1)         # (T*k, D)
    out = jnp.sum((rows * gates[:, None]).reshape(T, k, D), axis=1)
    return out, aux


def apply_moe(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    mesh = current_mesh()
    moe_p = p["moe"]
    tag = moe_p.get("_tag")
    ew_tree = {k: moe_p[k] for k in ("w_gate", "w_up", "w_down")}
    router_w = moe_p["router"]["w"]
    if mesh is not None and "model" in mesh.axis_names and mesh.shape["model"] > 1:
        ep = mesh.shape["model"]
        assert m.padded_experts % ep == 0, (m.padded_experts, ep)
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        in_spec = P(dp_axes if dp_axes else None, None, None)
        # expert weights: leading E axis shards over `model` (EP); works for
        # dense (E, d, f) and factorized {"B": (E, d, r), "C": (E, r, f)}
        ew_specs = jax.tree.map(
            lambda a: P("model", *([None] * (a.ndim - 1))), ew_tree)
        rt = P(*([None] * router_w.ndim))

        def body(rw, ew, xx):
            pp = {"router": rw, **ew}
            flat = xx.reshape(-1, D)
            out, aux = _moe_local(pp, m, flat, ep, "model")
            # tokens are replicated over 'model'; average the aux statistic
            return out.reshape(xx.shape), aux

        out, aux = shard_map(
            body, mesh=mesh,
            in_specs=(rt, ew_specs, in_spec),
            out_specs=(in_spec, P()),
            check_vma=False,
        )(router_w, ew_tree, x)
    else:
        pp = {"router": router_w, **ew_tree}
        out, aux = _moe_local(pp, m, x.reshape(-1, D), 1, None, tag=tag)
        out = out.reshape(B, S, D)

    if m.num_shared:
        sh = p["moe_shared"]
        g = jax.nn.silu(apply_linear(sh["w_gate"], x)) * apply_linear(sh["w_up"], x)
        shared_out = apply_linear(sh["w_down"], g)
        sgate = jax.nn.sigmoid(apply_linear(sh["shared_gate"], x))
        out = out + sgate * shared_out
    return out, aux
