"""Training launcher.

Single-host usage (CPU tests / claims experiments):
    PYTHONPATH=src python -m repro.launch.train --arch llama-mini \
        --steps 2000 --global-batch 8 --seq-len 128 --ckpt-dir runs/mini

Multi-pod usage: the same entry point with --mesh single|multi builds the
production mesh, shards params/optimizer with the logical rules
(repro.dist.sharding) and jits the identical train step with in/out
shardings — see repro/launch/dryrun.py for the lowering proof.
"""
from __future__ import annotations

import argparse
import json
import os
import sys



def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=500)
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--shard-id", type=int, default=0)
    ap.add_argument("--num-shards", type=int, default=1)
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of the arch")
    ap.add_argument("--override", default="",
                    help="JSON dict of ModelConfig field overrides")
    ap.add_argument("--history-out", default="")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.data.synthetic import DataConfig
    from repro.optim.adamw import OptimizerConfig
    from repro.train import step as TS
    from repro.train.loop import LoopConfig, Trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.override:
        cfg = cfg.replace(**json.loads(args.override))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.data_seed)
    tcfg = TS.TrainConfig(
        microbatches=args.microbatches,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=args.warmup,
                                  total_steps=args.steps))
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, log_every=args.log_every,
                      shard_id=args.shard_id, num_shards=args.num_shards,
                      heartbeat_path=args.heartbeat)
    trainer = Trainer(cfg, tcfg, dcfg, lcfg, seed=args.seed)
    result = trainer.run()
    for row in result["history"]:
        print(json.dumps(row))
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(result, f, indent=1)
    print(f"done: step={result['final_step']} "
          f"interrupted={result['interrupted']}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
