"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (jax locks the device count on first backend init — dryrun.py must
set XLA_FLAGS before any jax call).

Topology (TPU v5e pods):
  single-pod:  (data=16, model=16)        = 256 chips
  multi-pod:   (pod=2, data=16, model=16) = 512 chips
The `pod` axis composes with `data` into the DP/FSDP dimension (gradient
reduce-scatter intra-pod over ICI, all-reduce across pods over DCI);
`model` carries TP/SP/EP.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # older jax: meshes are Auto-typed
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by the sharded-smoke tests."""
    return _make_mesh((data, model), ("data", "model"))
