import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture × input-shape × mesh) cell
lowers AND compiles against the production meshes, and extract the roofline
terms from the compiled artifact.

MUST be run as its own process (the two lines above force 512 host devices
BEFORE jax initializes — never import this module from tests).

Per cell:
    jit(step).lower(...).compile()
    memory_analysis()      -> bytes/device (fits-or-not)
    cost_analysis()        -> HLO FLOPs + HBM bytes        (compute/memory terms)
    compiled.as_text()     -> collective ops + operand bytes (collective term)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --cells all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch qwen3-4b \
        --shape train_4k
Results are cached as JSON under experiments/dryrun/<mesh>/<cell>.json; use
--force to re-run. benchmarks/roofline.py consumes the JSONs.
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.train import step as TS

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                with_labels: bool) -> Dict:
    gb, S = shape.global_batch, shape.seq_len
    b: Dict = {}
    if cfg.is_encoder_decoder:
        b["enc_embeds"] = sds((gb, S, cfg.d_model), cfg.dtype)
        b["tokens"] = sds((gb, S), jnp.int32)
    elif cfg.frontend:
        b["embeds"] = sds((gb, S, cfg.d_model), cfg.dtype)
        if with_labels:
            b["labels"] = sds((gb, S), jnp.int32)
        if cfg.rope_kind == "mrope":
            b["positions"] = sds((3, gb, S), jnp.int32)
    else:
        b["tokens"] = sds((gb, S), jnp.int32)
    return b


def batch_shardings(batch: Dict, mesh) -> Dict:
    out = {}
    for k, v in batch.items():
        if k == "positions" and len(v.shape) == 3:
            axes = (None, "batch", "seq")
        else:
            axes = ("batch", "seq") + (None,) * (len(v.shape) - 2)
        out[k] = jax.sharding.NamedSharding(
            mesh, SH.shape_aware_spec(v.shape, axes, mesh))
    return out


CACHE_AXES = {
    # kv cache (n, B, L, K, hd): shard batch over dp, cache seq over model
    5: ("layer_stack", "batch", "kv_seq_model", None, None),
    4: ("layer_stack", "batch", None, None),
    3: ("layer_stack", "batch", None),
    2: ("layer_stack", "batch"),
}


def cache_shardings(cache, mesh):
    def leaf(v):
        nd = len(v.shape)
        if nd == 1:        # pos (B,)
            axes = ("batch",)
        else:
            axes = CACHE_AXES.get(nd, ("layer_stack",) + ("batch",)
                                  + (None,) * (nd - 2))
        return jax.sharding.NamedSharding(
            mesh, SH.shape_aware_spec(v.shape, axes, mesh))
    return jax.tree.map(leaf, cache)


# ---------------------------------------------------------------------------
# Model-FLOPs accounting (6·N·D train / 2·N·D inference, N = active matmul
# params; MoE counts the routed fraction top_k/E)
# ---------------------------------------------------------------------------
def active_matmul_params(cfg: ModelConfig, params_shapes) -> float:
    total = 0.0
    moe_scale = (cfg.moe.top_k / cfg.moe.num_experts
                 if cfg.moe.num_experts else 1.0)

    def walk(node, path):
        nonlocal total
        if hasattr(node, "shape"):
            if len(node.shape) < 2 or path[-1] in ("embed",):
                return
            scale = moe_scale if ("moe" in path and path[-1] in (
                "w_gate", "w_up", "w_down")) else 1.0
            # stacked runs carry their layer count in dim 0
            total += float(np.prod(node.shape)) * scale
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (k,))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (i,))

    walk(params_shapes, ())
    if cfg.tie_embeddings:
        total += float(cfg.vocab_size * cfg.d_model)   # logits matmul
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig, params_shapes) -> float:
    n = active_matmul_params(cfg, params_shapes)
    tokens = shape.global_batch * (shape.seq_len if shape.mode in
                                   ("train", "prefill") else 1)
    per_tok = 6.0 if shape.mode == "train" else 2.0
    return per_tok * n * tokens


# ---------------------------------------------------------------------------
# Collective parsing from post-SPMD HLO
# ---------------------------------------------------------------------------
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(%?[\w\.\-]+\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_RE = re.compile(r"^%?[\w\.\-]+ = ((?:\([^{]*?\))|(?:\S+)) ([\w\-]+)")


def parse_collectives(hlo: str) -> Dict:
    """Sum result bytes of collective ops, scaling ops inside while-loop
    bodies by the trip count (XLA annotates lax.scan loops with
    known_trip_count; fallback: largest constant in the loop condition)."""
    # ---- computation segmentation (headers end with '{', instructions
    # carry ' = '; header return types may contain /*index=N*/ comments) ---
    comps: Dict[str, str] = {}
    cur = None
    buf: list = []
    for line in hlo.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                head = line.split("(")[0]
                if " = " not in head:
                    m = _HEADER_RE.match(line)
                    if m:
                        cur = m.group(1)
                        buf = []
            continue
        if line.strip() == "}":
            comps[cur] = "\n".join(buf)
            cur = None
        else:
            buf.append(line)

    # ---- body computation -> trip count ----------------------------------
    trip: Dict[str, int] = {}
    for cname, body in comps.items():
        for line in body.splitlines():
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, wbody = m.group(1), m.group(2)
            t = None
            tm = _TRIP_RE.search(line)
            if tm:
                t = int(tm.group(1))
            else:
                consts = [int(c) for c in re.findall(
                    r"constant\((\d+)\)", comps.get(cond, ""))]
                t = max(consts) if consts else 1
            trip[wbody] = max(trip.get(wbody, 1), t)

    per_op: Dict[str, float] = {}
    total = 0.0
    for cname, body in comps.items():
        mult = trip.get(cname, 1)
        for line in body.splitlines():
            m = _INSTR_RE.match(line.strip())
            if not m:
                continue
            op = m.group(2)
            if op.endswith("-done"):
                continue                    # counted at -start
            base = op[:-6] if op.endswith("-start") else op
            if base not in _COLLECTIVES:
                continue
            byts = _shape_bytes(m.group(1)) * mult
            per_op[base] = per_op.get(base, 0.0) + byts
            total += byts
    return {"total_bytes": total, "per_op": per_op,
            "while_trip_counts": trip}


# ---------------------------------------------------------------------------
# Synthetic compressed-deploy shapes (uniform rank, MXU-aligned)
# ---------------------------------------------------------------------------
_COMPRESSIBLE = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                 "w_in", "w_z", "w_out", "w_bc", "ff_gate", "ff_up",
                 "ff_down"}


def factorized_shapes(tree, specs, ratio: float, multiple: int = 128):
    """Map dense linear {w} shape-structs to factorized {B, C} at a uniform
    parameter ratio (shape-level plan for dry-running the deploy form)."""
    def walk(node, spec, path):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "shape") \
                    and path and path[-1] in _COMPRESSIBLE \
                    and ("decoder" in path or "encoder" in path):
                w = node["w"]
                *stack, d1, d2 = w.shape
                r = int((1 - ratio) * d1 * d2 / (d1 + d2))
                r = max(multiple, r // multiple * multiple)
                r = min(r, d1, d2)
                wspec = spec["w"]
                st = tuple(wspec[:-2])
                new = {
                    "B": sds((*stack, d1, r), w.dtype),
                    "C": sds((*stack, r, d2), w.dtype),
                }
                nspec = {"B": st + (wspec[-2], "rank"),
                         "C": st + ("rank", wspec[-1])}
                if "b" in node:
                    new["b"] = node["b"]
                    nspec["b"] = spec["b"]
                return new, nspec
            out_n, out_s = {}, {}
            for k in node:
                out_n[k], out_s[k] = walk(node[k], spec[k], path + (k,))
            return out_n, out_s
        return node, spec

    return walk(tree, specs, ())


# ---------------------------------------------------------------------------
# Per-cell lowering
# ---------------------------------------------------------------------------
def lower_cell(arch: str, shape_name: str, mesh, *, compressed: float = 0.0,
               microbatches: int = 1, donate: bool = True,
               overrides: Optional[Dict] = None,
               rules: Optional[Dict] = None,
               hlo_out: str = "", pallas_flash: bool = False) -> Dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}

    t0 = time.time()
    # abstract init: params as ShapeDtypeStructs; specs (string tuples) are
    # built host-side during the trace and captured by side effect
    holder = {}

    def _init(k):
        p, s = T.init_model(cfg, k)
        holder["specs"] = s
        return p

    params_shapes = jax.eval_shape(_init, jax.random.PRNGKey(0))
    specs = holder["specs"]
    if compressed > 0:
        params_shapes, specs = factorized_shapes(params_shapes, specs,
                                                 compressed)
    with SH.use_rules(rules or {}, mesh=mesh):
        p_shardings = SH.shardings_for_tree(params_shapes, specs, mesh)

    with mesh, SH.use_rules(rules or {}, mesh=mesh):
        if shape.mode == "train":
            tcfg = TS.TrainConfig(
                microbatches=microbatches,
                optimizer=OptimizerConfig(total_steps=10 ** 5))
            state_shapes = jax.eval_shape(
                lambda p: TS.TrainState(
                    params=p, opt=__import__(
                        "repro.optim.adamw", fromlist=["adamw_init"]
                    ).adamw_init(p)), params_shapes)
            opt_shardings = TS.AdamWState(
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                mu=p_shardings, nu=p_shardings)
            st_shardings = TS.TrainState(params=p_shardings,
                                         opt=opt_shardings)
            batch = batch_specs(cfg, shape, with_labels=True)
            b_shardings = batch_shardings(batch, mesh)
            fn = jax.jit(TS.make_train_step(cfg, tcfg),
                         in_shardings=(st_shardings, b_shardings),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_shapes, batch)
        elif shape.mode == "prefill":
            batch = batch_specs(cfg, shape, with_labels=False)
            b_shardings = batch_shardings(batch, mesh)
            fn = jax.jit(
                lambda p, b: T.prefill(p, cfg, b,
                                       max_len=shape.seq_len + 128),
                in_shardings=(p_shardings, b_shardings))
            lowered = fn.lower(params_shapes, batch)
        else:   # decode
            gb = shape.global_batch
            cache_shapes = jax.eval_shape(
                lambda: T.init_cache(cfg, gb, shape.seq_len,
                                     enc_len=min(shape.seq_len, 4096)))
            c_shardings = cache_shardings(cache_shapes, mesh)
            tok = sds((gb, 1), jnp.int32)
            tok_sh = jax.sharding.NamedSharding(
                mesh, SH.shape_aware_spec((gb, 1), ("batch", None), mesh))
            fn = jax.jit(
                lambda p, c, t: T.decode_step(p, cfg, c, t),
                in_shardings=(p_shardings, c_shardings, tok_sh),
                donate_argnums=(1,) if donate else ())
            lowered = fn.lower(params_shapes, cache_shapes, tok)
        t_lower = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax < 0.5: one dict per device
        cost = cost[0] if cost else {}
    hlo_txt = compiled.as_text()
    if hlo_out:
        import zstandard
        with open(hlo_out, "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                hlo_txt.encode()))
    from repro.launch import hlo_analysis
    an = hlo_analysis.analyze(hlo_txt, pallas_flash=pallas_flash)
    n_dev = mesh.size

    mf = model_flops(cfg, shape, params_shapes)
    # trip-count-aware totals from our own HLO analyzer (XLA's
    # cost_analysis does not scale while bodies — see hlo_analysis.py)
    hlo_flops = an["flops"]
    hlo_bytes = an["hbm_bytes"]
    coll_bytes = an["collective_bytes"]
    result = {
        "arch": arch, "shape": shape_name, "mesh": list(mesh.shape.values()),
        "mesh_axes": list(mesh.shape.keys()), "devices": n_dev,
        "mode": shape.mode, "compressed": compressed,
        "microbatches": microbatches, "pallas_flash": pallas_flash,
        "overrides": overrides or {}, "rules": rules or {},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"hlo_flops": hlo_flops, "hlo_bytes": hlo_bytes,
                 "xla_cost_flops": float(cost.get("flops", 0.0)),
                 "xla_cost_bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": {"total_bytes": coll_bytes,
                        "per_op": an["collectives"]},
        "model_flops": mf,
        "roofline": {
            "compute_s": hlo_flops / PEAK_FLOPS,
            "memory_s": hlo_bytes / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
            "useful_flops_ratio": mf / max(hlo_flops * n_dev, 1.0),
        },
    }
    terms = result["roofline"]
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    result["roofline"]["dominant"] = dom
    return result


def reanalyze_cell(json_path: str, hlo_path: str,
                   pallas_flash: bool = False) -> Optional[Dict]:
    """Recompute analyzer-derived fields from the saved HLO (no compile)."""
    if not (os.path.exists(json_path) and os.path.exists(hlo_path)):
        return None
    import zstandard
    with open(json_path) as f:
        res = json.load(f)
    if "roofline" not in res:
        return None
    with open(hlo_path, "rb") as f:
        hlo = zstandard.ZstdDecompressor().decompress(f.read()).decode()
    from repro.launch import hlo_analysis
    an = hlo_analysis.analyze(hlo, pallas_flash=pallas_flash)
    n_dev = res["devices"]
    res["pallas_flash"] = pallas_flash
    res["cost"]["hlo_flops"] = an["flops"]
    res["cost"]["hlo_bytes"] = an["hbm_bytes"]
    res["collectives"] = {"total_bytes": an["collective_bytes"],
                          "per_op": an["collectives"]}
    rf = {
        "compute_s": an["flops"] / PEAK_FLOPS,
        "memory_s": an["hbm_bytes"] / HBM_BW,
        "collective_s": an["collective_bytes"] / ICI_BW,
        "useful_flops_ratio": res["model_flops"] / max(
            an["flops"] * n_dev, 1.0),
    }
    rf["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                         key=lambda k: rf[k])
    res["roofline"] = rf
    return res


def cell_path(mesh_name: str, arch: str, shape: str, tag: str = "") -> str:
    d = os.path.join(RESULT_DIR, mesh_name)
    os.makedirs(d, exist_ok=True)
    sfx = f"__{tag}" if tag else ""
    return os.path.join(d, f"{arch}__{shape}{sfx}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--compressed", type=float, default=0.0,
                    help="also lower the factorized deploy form at this ratio")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--override", default="",
                    help='JSON ModelConfig overrides, e.g. {"remat":"dots"}')
    ap.add_argument("--rules", default="",
                    help='JSON logical-axis rule overrides, '
                         'e.g. {"seq":"model"}')
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--pallas-flash", action="store_true",
                    help="model the fused Pallas attention/lowrank kernels "
                         "in the HBM accounting (dots feeding only dots "
                         "stay in VMEM)")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute analysis from saved HLO (no compile)")
    args = ap.parse_args(argv)
    overrides = json.loads(args.override) if args.override else None
    rules = json.loads(args.rules) if args.rules else None
    if rules:
        rules = {k: (tuple(v) if isinstance(v, list) else v)
                 for k, v in rules.items()}

    assert len(jax.devices()) == 512, \
        f"dryrun needs 512 host devices, got {len(jax.devices())}"
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    archs = [a for a in archs if a != "llama-mini"]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            out = cell_path(args.mesh, arch, shape, args.tag)
            hlo_out = out.replace(".json", ".hlo.zst")
            if args.reanalyze:
                res = reanalyze_cell(out, hlo_out,
                                     pallas_flash=args.pallas_flash)
                if res is None:
                    continue
            elif os.path.exists(out) and not args.force:
                print(f"[cached] {arch} x {shape}")
                continue
            else:
                try:
                    res = lower_cell(arch, shape, mesh,
                                     compressed=args.compressed,
                                     microbatches=args.microbatches,
                                     overrides=overrides, rules=rules,
                                     hlo_out=hlo_out,
                                     pallas_flash=args.pallas_flash)
                except Exception as e:
                    res = {"arch": arch, "shape": shape, "error":
                           f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
            with open(out, "w") as f:
                json.dump(res, f, indent=1)
            if res.get("skipped"):
                n_skip += 1
                print(f"[skip]   {arch} x {shape}: {res['reason']}")
            elif "error" in res:
                n_fail += 1
                print(f"[FAIL]   {arch} x {shape}: {res['error'][:200]}")
            else:
                n_ok += 1
                r = res["roofline"]
                print(f"[ok]     {arch} x {shape} dominant={r['dominant']} "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"(compile {res['compile_s']}s)")
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
