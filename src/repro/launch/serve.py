"""Serving launcher: load a checkpoint (optionally D-Rank-compress it on
the fly, or boot straight from a saved compressed artifact), start the
continuous-batching engine, run a synthetic request workload, and report
latency/throughput.

    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --ckpt runs/mini_mha --compress drank --ratio 0.3 \
        --save-compressed runs/mini_drank30 --requests 16 --n-new 32

    # later: serve the artifact directly (no calibration/SVD at boot);
    # --verify re-checks the manifest content hashes first
    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --compressed-ckpt runs/mini_drank30 --verify --requests 16 \
        --n-new 32

    # resilient serving: bounded queue, deadlines, elastic-rank
    # degradation, liveness heartbeats, structured metrics — and a
    # deterministic fault plan for chaos drills (DESIGN.md §5)
    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --requests 32 --max-queue 16 --deadline-s 30 --elastic \
        --watchdog-s 60 --heartbeat-dir runs/hb \
        --fault-plan '{"nan_decode_step": 3}' --stats-json runs/serve.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--compress", default="",
                    choices=["", *__import__("repro.core.compress",
                                             fromlist=["METHODS"]).METHODS])
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--compressed-ckpt", default="",
                    help="boot from a compress.save_plan artifact "
                         "(skips --ckpt/--compress)")
    ap.add_argument("--save-compressed", default="",
                    help="after --compress, persist the artifact here")
    ap.add_argument("--verify", action="store_true",
                    help="with --compressed-ckpt: re-hash the stored "
                         "arrays against the manifest content hashes "
                         "before booting")
    ap.add_argument("--eager-capture", action="store_true",
                    help="calibrate with the eager host oracle instead of "
                         "the jit/device streaming capture")
    ap.add_argument("--whiten-stream", action="store_true",
                    help="stream whitening Cholesky factors instead of "
                         "Grams during calibration (QR updates; the Gram "
                         "is never materialized — DESIGN.md §1.5/§1.6)")
    ap.add_argument("--calib-mesh-shards", type=int, default=0,
                    help="calibrate over a (data=N) mesh of local "
                         "devices (sharded batch + accumulators; needs "
                         ">= N devices, e.g. a TPU host or "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N); 0 = single-device capture")
    ap.add_argument("--shard-grams-above", type=int, default=4096,
                    help="with --calib-mesh-shards: feature dim at which "
                         "calibration (D,D) accumulators shard row-wise "
                         "over the mesh data axes instead of replicating")
    ap.add_argument("--calib-samples", type=int, default=16,
                    help="calibration samples for --compress")
    ap.add_argument("--calib-seq", type=int, default=128,
                    help="calibration sequence length for --compress")
    ap.add_argument("--device-compress", action="store_true",
                    help="run the compression math (whitening/SVD/refine) "
                         "on device via the batched numerics_jax backend "
                         "instead of the host fp64 loop")
    ap.add_argument("--rsvd-threshold", type=int, default=0,
                    help="with --device-compress: min-side size above "
                         "which the exact eigh switches to randomized SVD")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # --- resilience (DESIGN.md §5) ----------------------------------------
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the wait queue; submits past the bound "
                         "are rejected with backpressure (0 = unbounded)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline; requests still "
                         "queued past it are deterministically shed")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="poison-quarantine re-queue budget before a "
                         "request fails typed")
    ap.add_argument("--elastic", action="store_true",
                    help="serve-time elastic rank: degrade factorized "
                         "decode rank to pow2 buckets under queue "
                         "pressure, restore when drained")
    ap.add_argument("--elastic-levels", type=int, default=2,
                    help="with --elastic: degraded rank buckets below "
                         "full rank")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    help="drain watchdog: report the run as stalled "
                         "after this long without forward progress")
    ap.add_argument("--heartbeat-dir", default="",
                    help="beat a liveness heartbeat file here every "
                         "engine step (dist.ft; readable by "
                         "detect_stalled / StallDetector)")
    ap.add_argument("--fault-plan", default="",
                    help="inject deterministic faults: a JSON FaultPlan "
                         "or @path/to/plan.json (dist.faultinject; "
                         "chaos drills only)")
    ap.add_argument("--load-retries", type=int, default=0,
                    help="with --compressed-ckpt: retry a transiently "
                         "failing load with backoff, quarantining the "
                         "artifact if it keeps failing integrity")
    ap.add_argument("--stats-json", default="",
                    help="write the structured serve-metrics dict "
                         "(queue/shed/retry counters, TTFT percentiles, "
                         "rank-bucket residency) to this path")
    args = ap.parse_args(argv)

    from repro.ckpt import store
    from repro.configs import get_config
    from repro.core import compress as CC
    from repro.data.synthetic import DataConfig, calibration_batches
    from repro.models import transformer as T
    from repro.serve import admission as adm
    from repro.serve.engine import ContinuousBatcher, Request, ServeConfig
    from repro.train import step as TS

    cfg = get_config(args.arch)
    scfg = ServeConfig(batch=args.slots, max_len=args.max_len)
    acfg = adm.AdmissionConfig(max_queue=args.max_queue,
                               default_deadline_s=args.deadline_s,
                               max_retries=args.max_retries,
                               elastic=args.elastic,
                               elastic_levels=args.elastic_levels)
    faults = None
    if args.fault_plan:
        from repro.dist.faultinject import FaultPlan
        faults = FaultPlan.from_json(args.fault_plan)
        print(f"fault plan armed: {faults.to_json()}")
    heartbeat = None
    if args.heartbeat_dir:
        import os

        from repro.dist.ft import Heartbeat
        heartbeat = Heartbeat(os.path.join(args.heartbeat_dir,
                                           "worker0.json"), fault=faults)
    resil = dict(admission=acfg, faults=faults, heartbeat=heartbeat)
    if args.compressed_ckpt:
        cb = ContinuousBatcher.from_compressed(
            args.compressed_ckpt, cfg, scfg, verify=args.verify,
            retries=args.load_retries, quarantine=args.load_retries > 0,
            **resil)
        print(f"booted from compressed checkpoint {args.compressed_ckpt} "
              f"({cb.plan.summary['achieved_ratio']:.1%} removed, "
              f"method={cb.plan.config.method}"
              + (", integrity verified" if args.verify else "") + ")")
    else:
        if args.ckpt:
            state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0))
            step, state = store.restore(args.ckpt, state)
            params = state.params
            print(f"loaded {args.ckpt} @ step {step}")
        else:
            params, _ = T.init_model(cfg, jax.random.PRNGKey(args.seed))
            print("serving a randomly initialized model (no --ckpt)")

        if args.compress:
            if args.whiten_stream and args.eager_capture:
                ap.error("--whiten-stream needs the streaming capture; "
                         "drop --eager-capture (the eager fp64 oracle "
                         "always materializes Grams)")
            calib_batch = 8           # rows per calibration batch
            mesh = None
            if args.calib_mesh_shards > 1:
                if args.eager_capture:
                    ap.error("--calib-mesh-shards needs the streaming "
                             "capture; drop --eager-capture")
                # shard_map splits batch ROWS over the data axis: the
                # calibration batch must divide, and a ragged final
                # batch (calib_samples % calib_batch) would too — fail
                # at parse time, not deep inside lowering
                if calib_batch % args.calib_mesh_shards != 0:
                    ap.error(f"--calib-mesh-shards "
                             f"{args.calib_mesh_shards} must divide the "
                             f"calibration batch of {calib_batch} rows")
                if args.calib_samples % calib_batch != 0:
                    ap.error(f"--calib-samples {args.calib_samples} "
                             f"must be a multiple of {calib_batch} with "
                             f"--calib-mesh-shards (a ragged final "
                             f"batch cannot split over the mesh)")
                n_dev = len(jax.devices())
                if n_dev < args.calib_mesh_shards:
                    ap.error(f"--calib-mesh-shards {args.calib_mesh_shards}"
                             f" but only {n_dev} local devices (set "
                             f"XLA_FLAGS=--xla_force_host_platform_"
                             f"device_count={args.calib_mesh_shards} to "
                             f"fake a host mesh)")
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(data=args.calib_mesh_shards, model=1)
            import jax.numpy as jnp
            dcfg = DataConfig(vocab_size=cfg.vocab_size,
                              seq_len=args.calib_seq,
                              global_batch=calib_batch)
            calib = [{"tokens": jnp.asarray(b["tokens"])}
                     for b in calibration_batches(
                         dcfg, args.calib_samples, calib_batch)]
            ccfg = CC.CompressionConfig(method=args.compress,
                                        ratio=args.ratio,
                                        group_size=args.group_size,
                                        beta=args.beta,
                                        rsvd_threshold=args.rsvd_threshold)
            params, plan = CC.build_plan_and_params(
                params, cfg, ccfg, calib,
                streaming=not args.eager_capture,
                device=args.device_compress,
                mesh=mesh,
                whiten_tags=(True if args.whiten_stream else None),
                shard_grams_above=args.shard_grams_above)
            print(f"compressed with {args.compress}: "
                  f"{plan.summary['achieved_ratio']:.1%} removed")
            if args.save_compressed:
                path = CC.save_plan(args.save_compressed, params, plan, cfg)
                print(f"saved compressed artifact to {path}")
        cb = ContinuousBatcher(params, cfg, scfg, **resil)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    accepted = 0
    for i in range(args.requests):
        accepted += cb.submit(Request(
            rid=i,
            tokens=rng.integers(0, cfg.vocab_size,
                                size=(args.prompt_len,), dtype=np.int32),
            n_new=args.n_new))
    if accepted < args.requests:
        print(f"backpressure: {args.requests - accepted}/{args.requests} "
              f"requests rejected at submit (--max-queue {args.max_queue})")
    done = cb.run_until_drained(watchdog_s=args.watchdog_s)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    lat = [r.t_done - r.t_submit for r in done]
    report = {
        "drain_status": done.status,   # drained | timeout | stalled
        "requests": len(done),
        "shed": len(done.shed),
        "rejected": len(done.rejected),
        "failed": len(done.failed),
        "generated_tokens": toks,
        "tokens_per_s": round(toks / dt, 1) if toks else 0.0,
        "mean_latency_s": round(float(np.mean(lat)), 3) if lat else 0.0,
        "p95_latency_s": (round(float(np.percentile(lat, 95)), 3)
                          if lat else 0.0),
        "engine_stats": cb.stats,     # jit retraces, admissions
    }
    print(json.dumps(report, indent=1))
    if done.status != "drained":
        undone = [r.rid for r in done.undrained]
        print(f"WARNING: drain ended '{done.status}' with "
              f"{len(undone)} requests unfinished: {undone[:8]}")
    for r in done.failed:
        print(f"FAILED rid={r.rid}: {r.error}")
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(cb.metrics(), f, indent=1)
        print(f"serve metrics written to {args.stats_json}")
    return 0 if done.status == "drained" else 1


if __name__ == "__main__":
    raise SystemExit(main())
