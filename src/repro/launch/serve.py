"""Serving launcher: a thin argparse front over the typed public API in
``repro.serve.api`` (ServeOptions / load_engine / serve — DESIGN.md
§5.6). Every flag maps 1:1 onto a :class:`ServeOptions` field; all
validation and behavior lives in the API module, so anything this CLI
can do a Python caller can do with the dataclass.

    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --ckpt runs/mini_mha --compress drank --ratio 0.3 \
        --save-compressed runs/mini_drank30 --requests 16 --n-new 32

    # later: serve the artifact directly, AOT-compiled — a warm
    # compilation cache boots to first token without retracing
    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --compressed-ckpt runs/mini_drank30 --verify --aot \
        --requests 16 --n-new 32

    # resilient serving at scale: two replicas behind the router,
    # bounded queues, deadlines, elastic-rank degradation
    PYTHONPATH=src python -m repro.launch.serve --arch llama-mini \
        --requests 32 --replicas 2 --max-queue 16 --deadline-s 30 \
        --elastic --stats-json runs/serve.json
"""
from __future__ import annotations

import argparse
import json
import warnings


def build_parser() -> argparse.ArgumentParser:
    """Flags mirror ``ServeOptions`` fields (``-`` ↔ ``_``); deprecated
    spellings keep working via ``parse_serve_options``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt", default="")
    from repro.core.compress import METHODS
    ap.add_argument("--compress", default="", choices=["", *METHODS])
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--compressed-ckpt", default="",
                    help="boot from a compress.save_plan artifact "
                         "(skips --ckpt/--compress)")
    ap.add_argument("--save-compressed", default="",
                    help="after --compress, persist the artifact here")
    ap.add_argument("--verify", action="store_true",
                    help="with --compressed-ckpt: re-hash the stored "
                         "arrays against the manifest content hashes "
                         "before booting")
    ap.add_argument("--eager-capture", action="store_true",
                    help="calibrate with the eager host oracle instead of "
                         "the jit/device streaming capture")
    ap.add_argument("--whiten-stream", action="store_true",
                    help="stream whitening Cholesky factors instead of "
                         "Grams during calibration (QR updates; the Gram "
                         "is never materialized — DESIGN.md §1.5/§1.6)")
    ap.add_argument("--calib-mesh-shards", type=int, default=0,
                    help="calibrate over a (data=N) mesh of local "
                         "devices (sharded batch + accumulators; needs "
                         ">= N devices, e.g. a TPU host or "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N); 0 = single-device capture")
    ap.add_argument("--shard-grams-above", type=int, default=4096,
                    help="with --calib-mesh-shards: feature dim at which "
                         "calibration (D,D) accumulators shard row-wise "
                         "over the mesh data axes instead of replicating")
    ap.add_argument("--calib-samples", type=int, default=16,
                    help="calibration samples for --compress")
    ap.add_argument("--calib-seq", type=int, default=128,
                    help="calibration sequence length for --compress")
    ap.add_argument("--device-compress", action="store_true",
                    help="run the compression math (whitening/SVD/refine) "
                         "on device via the batched numerics_jax backend "
                         "instead of the host fp64 loop")
    ap.add_argument("--rsvd-threshold", type=int, default=0,
                    help="with --device-compress: min-side size above "
                         "which the exact eigh switches to randomized SVD")
    ap.add_argument("--batch", type=int, default=None,
                    help="decode slots (continuous-batching width)")
    ap.add_argument("--slots", type=int, default=None,
                    help=argparse.SUPPRESS)   # deprecated alias of --batch
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--kv-block", type=int, default=0,
                    help="paged KV cache: block size in tokens (multiple "
                         "of 8, divides --max-len); 0 = the contiguous "
                         "per-slot pool (DESIGN.md §5.7)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="with --kv-block: requests sharing a prompt "
                         "prefix refcount the same immutable KV blocks; "
                         "admission prefills only the unshared tail "
                         "(copy-on-write fork at the divergence block)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--n-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # --- resilience (DESIGN.md §5) ----------------------------------------
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the wait queue; submits past the bound "
                         "are rejected with backpressure (0 = unbounded)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline; requests still "
                         "queued past it are deterministically shed")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="poison-quarantine re-queue budget before a "
                         "request fails typed")
    ap.add_argument("--reject-overlong", action="store_true",
                    help="shed prompts longer than max_len - 1 with a "
                         "typed shed_overlong status instead of "
                         "truncating them to their newest tokens")
    ap.add_argument("--elastic", action="store_true",
                    help="serve-time elastic rank: degrade factorized "
                         "decode rank to pow2 buckets under queue "
                         "pressure, restore when drained")
    ap.add_argument("--elastic-levels", type=int, default=2,
                    help="with --elastic: degraded rank buckets below "
                         "full rank")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    help="drain watchdog: report the run as stalled "
                         "after this long without forward progress")
    ap.add_argument("--heartbeat-dir", default="",
                    help="beat a liveness heartbeat file here every "
                         "engine step (dist.ft; readable by "
                         "detect_stalled / StallDetector)")
    ap.add_argument("--fault-plan", default="",
                    help="inject deterministic faults: a JSON FaultPlan "
                         "or @path/to/plan.json (dist.faultinject; "
                         "chaos drills only)")
    ap.add_argument("--load-retries", type=int, default=0,
                    help="with --compressed-ckpt: retry a transiently "
                         "failing load with backoff, quarantining the "
                         "artifact if it keeps failing integrity")
    ap.add_argument("--stats-json", default="",
                    help="write the structured serve-metrics dict "
                         "(queue/shed/retry counters, TTFT percentiles, "
                         "rank-bucket residency) to this path")
    # --- front door -------------------------------------------------------
    ap.add_argument("--aot", action="store_true",
                    help="AOT-compile the serving surface at boot, backed "
                         "by the persistent compilation cache keyed on "
                         "the artifact fingerprint (serve/aot.py); a "
                         "warm cache boots without any XLA compiles")
    ap.add_argument("--aot-cache-dir", default="",
                    help="compilation cache location (default "
                         "$REPRO_AOT_CACHE or ~/.cache/repro/aot)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="run N engine replicas behind one router that "
                         "places requests on the least-loaded replica "
                         "and spills on backpressure")
    ap.add_argument("--stream", action="store_true",
                    help="drive the workload through the async front "
                         "door (token streaming) even with --replicas 1")
    # --- observability (DESIGN.md §6) -------------------------------------
    ap.add_argument("--trace-out", default="",
                    help="record the run as Chrome-trace JSON here "
                         "(load in https://ui.perfetto.dev or "
                         "chrome://tracing)")
    ap.add_argument("--device-trace-dir", default="",
                    help="with --trace-out or alone: capture a "
                         "jax.profiler device timeline into this logdir")
    ap.add_argument("--metrics-json", default="",
                    help="write the live v2 metrics snapshot here on a "
                         "fixed cadence (plus once at the end)")
    ap.add_argument("--metrics-interval-s", type=float, default=1.0,
                    help="cadence for --metrics-json")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus text metrics on this port "
                         "(0 = ephemeral; -1 = off)")
    ap.add_argument("--flightrec-dir", default="",
                    help="arm the flight recorder: dump a debug artifact "
                         "here whenever a request fails typed or a drain "
                         "ends non-drained")
    return ap


def parse_serve_options(argv=None):
    """argv → :class:`repro.serve.api.ServeOptions`. Deprecated flags
    are translated here (with a ``DeprecationWarning``) so the options
    object only ever sees canonical names."""
    from repro.serve.api import ServeOptions

    ap = build_parser()
    args = ap.parse_args(argv)
    if args.slots is not None:
        warnings.warn("--slots is deprecated; use --batch",
                      DeprecationWarning, stacklevel=2)
        if args.batch is None:
            args.batch = args.slots
    if args.batch is None:
        args.batch = 4
    fields = {f.name for f in ServeOptions.__dataclass_fields__.values()}
    kw = {k: v for k, v in vars(args).items() if k in fields}
    try:
        return ServeOptions(**kw)
    except ValueError as e:
        ap.error(str(e))


def main(argv=None) -> int:
    from repro.serve.api import serve

    opts = parse_serve_options(argv)
    res = serve(opts, echo=print)
    print(json.dumps(res.report, indent=1))
    if res.status != "drained":
        undone = [r.rid for r in res.undrained]
        print(f"WARNING: drain ended '{res.status}' with "
              f"{len(undone)} requests unfinished: {undone[:8]}")
    for r in res.failed:
        print(f"FAILED rid={r.rid}: {r.error}")
    return 0 if res.status == "drained" else 1


if __name__ == "__main__":
    raise SystemExit(main())
