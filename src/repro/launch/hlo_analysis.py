"""Static analyzer for post-SPMD optimized HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis does not scale
``while``-loop bodies by their trip counts, so a lax.scan over 32 layers
(or a chunked-attention nested scan) under-reports FLOPs and bytes by the
trip factor. The dry-run's roofline terms need loop-aware totals, so we
re-derive them from the HLO text, where XLA conveniently annotates
``known_trip_count`` on every scan-lowered loop.

What it computes, per device (the module is already SPMD-partitioned):
  flops            2·M·N·K for every dot (+ convolutions via output×kernel)
  hbm_bytes        fusion-boundary traffic: every top-level instruction
                   writes its result once and reads its non-trivial
                   operands once (fusions are a single node — their
                   internals stay in registers/VMEM)
  collective_bytes result bytes of all-gather/all-reduce/reduce-scatter/
                   all-to-all/collective-permute (per collective family)
All three are scaled by the product of enclosing-loop trip counts via
multiplier propagation over the computation call graph (calls=, body=,
condition=, to_apply=).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+((?:\([^{]*?\))|(?:\S+))\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "iota"}


def _shape_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """Bytes + list of dim-lists of every array in the (tuple) type."""
    total = 0
    dims_all = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_all.append(ds)
    return total, dims_all


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    result_bytes: int = 0


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    # call edges: (callee, trip_multiplier_for_callee, kind)
    calls: List[Tuple[str, int, str]] = field(default_factory=list)


_LAYOUT_RE = re.compile(r"\]\{[\d,]*\}")


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    hlo = _LAYOUT_RE.sub("]", hlo)      # strip layout annotations
    for line in hlo.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                head = line.split("(")[0]
                if " = " not in head:
                    m = _HEADER_RE.match(line)
                    if m:
                        cur = Computation(m.group(1))
                        if line.lstrip().startswith("ENTRY"):
                            entry_name = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, tstr, op, rest = m.groups()
        rb, _ = _shape_info(tstr)
        inst = Instr(name=name, type_str=tstr, op=op, rest=rest,
                     result_bytes=rb)
        cur.instrs.append(inst)
        # call edges
        trip = 1
        tm = _TRIP_RE.search(line)
        if op == "while":
            trip = int(tm.group(1)) if tm else 1
        kind = "while" if op == "while" else "inline"
        for cm in _CALLED_RE.finditer(line):
            cur.calls.append((cm.group(1), trip if op == "while" else 1,
                              kind))
        for cm in _BRANCHES_RE.finditer(line):
            for callee in re.split(r",\s*%?", cm.group(1)):
                if callee.strip():
                    cur.calls.append((callee.strip().lstrip("%"), 1, kind))
    if entry_name is not None and entry_name in comps:
        comps["__entry__"] = comps[entry_name]
    return comps


def _multipliers(comps: Dict[str, Computation]
                 ) -> Tuple[Dict[str, int], set]:
    """Returns (per-computation trip multiplier, set of top-level
    computations). Top-level = entry + while bodies/conditions reached
    transitively through while edges (their instructions touch HBM);
    everything else is fusion/reduction internals."""
    entry = comps.get("__entry__")
    if entry is None:
        return {c: 1 for c in comps}, set(comps)
    mult: Dict[str, int] = {entry.name: 1}
    top = {entry.name}
    frontier = [entry.name]
    guard = 0
    while frontier and guard < 100000:
        guard += 1
        cname = frontier.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        base = mult.get(cname, 1)
        for callee, trip, kind in comp.calls:
            m = base * trip
            if kind == "while" and cname in top:
                top.add(callee)
            if m > mult.get(callee, 0):
                mult[callee] = m
                frontier.append(callee)
    return mult, top


def _dot_flops(inst: Instr, symtab: Dict[str, List[int]]) -> float:
    """2 × prod(result dims) × contracted size (batch dims are in result)."""
    _, res_dims = _shape_info(inst.type_str)
    if not res_dims:
        return 0.0
    out_elems = 1
    for d in res_dims[0]:
        out_elems *= d
    # contracted size from lhs shape + lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    ops = re.findall(r"%([\w\.\-]+)", inst.rest.split(")")[0])
    csize = 1
    if mc and ops:
        lhs_dims = symtab.get(ops[0])
        if lhs_dims:
            for ax in mc.group(1).split(","):
                if ax and int(ax) < len(lhs_dims):
                    csize *= lhs_dims[int(ax)]
    return 2.0 * out_elems * csize


def _conv_flops(inst: Instr, symtab: Dict[str, List[int]]) -> float:
    _, res_dims = _shape_info(inst.type_str)
    if not res_dims:
        return 0.0
    out_elems = 1
    for d in res_dims[0]:
        out_elems *= d
    ops = re.findall(r"%([\w\.\-]+)", inst.rest.split(")")[0])
    if len(ops) >= 2 and symtab.get(ops[1]):
        kelems = 1
        for d in symtab[ops[1]]:
            kelems *= d
        # divide by output-feature dim (already in out_elems)
        kd = symtab[ops[1]]
        of = max(kd[-1], 1) if kd else 1
        return 2.0 * out_elems * (kelems / of)
    return 0.0


# ops whose results a TPU compiler keeps in registers/VMEM by fusing into
# the consumer; everything else materializes in HBM
_FUSIBLE = {
    "add", "subtract", "multiply", "divide", "power", "negate", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sqrt", "rsqrt", "cbrt", "sine", "cosine", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "is-finite", "not", "and",
    "or", "xor", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "maximum", "minimum", "compare", "select",
    "clamp", "convert", "bitcast-convert", "broadcast", "reshape",
    "bitcast", "transpose", "slice", "iota", "constant", "reverse",
    "map", "expand", "real", "imag", "complex", "reduce-precision",
    "stochastic-convert", "copy-start", "copy-done",
}
_ALIAS = {"tuple", "get-tuple-element", "while", "conditional",
          "parameter", "after-all", "opt-barrier", "partition-id",
          "replica-id", "domain", "token"}


# ops that read/write only their WINDOW, not their full operand/result:
# dynamic-slice reads as many bytes as it produces; dynamic-update-slice
# writes (and reads) only the update operand — the big buffer is aliased.
_WINDOW_READ = {"dynamic-slice", "gather"}
_WINDOW_WRITE = {"dynamic-update-slice", "scatter"}


_CALLS_ONE_RE = re.compile(r"calls=%?([\w\.\-]+)")

# fusions made ONLY of these ops are dtype/layout plumbing; on TPU the dot
# consumes the original value natively (the CPU backend promotes bf16
# matmuls to f32 and hoists whole-weight converts — a backend artifact the
# roofline must not count)
_PLUMBING = {"parameter", "convert", "copy", "bitcast", "bitcast-convert",
             "reshape", "transpose", "broadcast", "constant", "tuple"}


def _fusion_kind(inst: Instr, comps: Dict[str, "Computation"]) -> str:
    """Classify a fusion by its internals: 'dus' (in-place windowed write,
    e.g. a KV-cache update — the big buffer is donated/aliased), 'slice'
    (windowed extraction), 'convert' (dtype/layout plumbing — alias), or
    'dense'."""
    m = _CALLS_ONE_RE.search(inst.rest)
    if not m:
        return "dense"
    callee = comps.get(m.group(1))
    if callee is None:
        return "dense"
    ops = {i.op for i in callee.instrs}
    if ops <= _PLUMBING:
        return "convert"
    if "dynamic-update-slice" in ops:
        return "dus"
    if ("dynamic-slice" in ops or "gather" in ops) and \
            not ops & {"dot", "dot-general", "convolution"}:
        return "slice"
    return "dense"


def _comp_hbm(comp: Computation, comps: Dict[str, "Computation"],
              pallas_flash: bool = False) -> float:
    """Ideal-fusion HBM bytes for one execution of a top-level computation.

    pallas_flash=True additionally models the fused attention kernel: a dot
    whose result feeds (through fusible chains) ONLY other dots in the same
    computation is VMEM-resident — neither its write nor those reads touch
    HBM. This is exactly what kernels/flash_attention.py and
    kernels/lowrank_matmul.py do on real hardware.
    """
    instrs = {i.name: i for i in comp.instrs}
    fkind = {i.name: _fusion_kind(i, comps) for i in comp.instrs
             if i.op == "fusion"}

    operand_cache: Dict[str, Tuple[str, ...]] = {}

    def operands(name: str) -> Tuple[str, ...]:
        if name not in operand_cache:
            inst = instrs[name]
            ostr = inst.rest.split(")")[0]
            operand_cache[name] = tuple(
                o for o in re.findall(r"%([\w\.\-]+)", ostr) if o in instrs)
        return operand_cache[name]

    def _transparent(inst: Instr) -> bool:
        return inst.op in _FUSIBLE or fkind.get(inst.name) == "convert"

    resolve_cache: Dict[str, frozenset] = {}

    def resolve(name: str, depth: int = 0) -> frozenset:
        """Materialized source values feeding `name` through fusible ops."""
        if name in resolve_cache:
            return resolve_cache[name]
        if depth > 64:
            return frozenset({name})
        inst = instrs[name]
        if _transparent(inst):
            out = frozenset().union(*[resolve(o, depth + 1)
                                      for o in operands(name)]) \
                if operands(name) else frozenset()
        elif inst.op in ("tuple", "while", "conditional"):
            out = frozenset()      # elements flow via get-tuple-element
        else:
            out = frozenset({name})
        resolve_cache[name] = out
        return out

    material = [i for i in comp.instrs
                if not _transparent(i) and i.op not in _ALIAS
                and not i.op.endswith("-done")]
    src_map = {i.name: (frozenset().union(
        *[resolve(o, 1) for o in operands(i.name)])
        if operands(i.name) else frozenset()) for i in material}

    vmem: frozenset = frozenset()
    if pallas_flash:
        # dots read only by dots/reduces -> resident (the flash kernel keeps
        # the score tile, its row-max/sum reductions, and the PV matmul all
        # in VMEM; same for lowrank_matmul's rank-k intermediate)
        def is_reduce_like(i: Instr) -> bool:
            if i.op in ("reduce", "reduce-window"):
                return True
            if i.op != "fusion":
                return False
            m = _CALLS_ONE_RE.search(i.rest)
            callee = comps.get(m.group(1)) if m else None
            if callee is None:
                return False
            ops = {x.op for x in callee.instrs}
            return bool(ops & {"reduce", "reduce-window"}) and \
                not ops & {"dot", "dot-general", "convolution"}

        dot_names = {i.name for i in material
                     if i.op in ("dot", "dot-general")}
        fused_ok = dot_names | {i.name for i in material
                                if is_reduce_like(i)}
        consumers: Dict[str, set] = {}
        for i in material:
            for s in src_map[i.name]:
                consumers.setdefault(s, set()).add(i.name)
        vmem = frozenset(
            d for d in dot_names
            if consumers.get(d) and consumers[d] <= fused_ok)

    total = 0.0
    for inst in material:
        kind = "dense"
        if inst.op in _WINDOW_WRITE:
            kind = "dus"
        elif inst.op in _WINDOW_READ:
            kind = "slice"
        elif inst.op == "fusion":
            kind = _fusion_kind(inst, comps)

        srcs = src_map[inst.name]
        max_src = max((instrs[s].result_bytes for s in srcs), default=0)
        sum_src = sum(instrs[s].result_bytes for s in srcs)
        # ---- write ---------------------------------------------------------
        if inst.name in vmem:
            pass
        elif kind == "dus":
            # in-place window update: writes ~ (result - aliased buffer)
            total += max(inst.result_bytes - max_src, 0)
        else:
            total += inst.result_bytes
        # ---- reads ---------------------------------------------------------
        if kind == "slice":
            total += inst.result_bytes        # reads what it produces
        elif kind == "dus":
            total += max(sum_src - max_src, 0)   # the update, not the buffer
        else:
            for s in srcs:
                if s in vmem:
                    continue
                total += instrs[s].result_bytes
    return total


def analyze(hlo: str, pallas_flash: bool = False) -> Dict:
    comps = parse_module(hlo)
    mult, top_level = _multipliers(comps)
    flops = 0.0
    hbm = 0.0
    coll: Dict[str, float] = {}
    coll_total = 0.0
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0)
        if m == 0:
            continue        # unreachable
        # symbol table: instr name -> (dims of first array, result bytes)
        symtab: Dict[str, List[int]] = {}
        rbytes: Dict[str, int] = {}
        for inst in comp.instrs:
            _, dims = _shape_info(inst.type_str)
            symtab[inst.name] = dims[0] if dims else []
            rbytes[inst.name] = inst.result_bytes

        for inst in comp.instrs:
            if inst.op in ("dot", "dot-general"):
                flops += m * _dot_flops(inst, symtab)
            elif inst.op == "convolution":
                flops += m * _conv_flops(inst, symtab)
            base = inst.op[:-6] if inst.op.endswith("-start") else inst.op
            if base in _COLLECTIVES and not inst.op.endswith("-done"):
                b = m * inst.result_bytes
                coll[base] = coll.get(base, 0.0) + b
                coll_total += b

        # HBM traffic (ideal-fusion model): only MATERIALIZED values touch
        # HBM. Elementwise/shape ops fuse into their consumers (as the TPU
        # compiler does), so a read through a fusible chain resolves back
        # to its materialized sources. Tuples/while carries are aliases.
        if cname in top_level:
            hbm += m * _comp_hbm(comp, comps, pallas_flash=pallas_flash)
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": coll_total,
        "collectives": coll,
        "multipliers": {k: v for k, v in mult.items() if v > 1},
    }
