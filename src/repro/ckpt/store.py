"""Checkpointing: atomic, async, manifest-driven, elastic-reshard-on-load.

Layout:
    <dir>/step_000042/arrays.npz       flat {escaped path -> np array}
    <dir>/step_000042/manifest.json    step, keys, shapes, dtypes, user meta
    <dir>/LATEST                       atomic pointer (text: "step_000042")

Restore takes a *template* pytree (same structure as saved; e.g. a freshly
initialized TrainState) plus optional per-leaf shardings for the CURRENT
mesh — a job restarted on a different topology reshards on load (elastic).

Async mode: the host copy (device_get) happens synchronously — cheap and
consistent — and the disk write runs on a worker thread off the train loop's
critical path.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "␟"      # unit-separator glyph: safe path joiner for npz keys


class IntegrityError(ValueError):
    """A stored artifact failed verification (sha256 mismatch, truncated
    or unreadable blob, missing arrays). Subclasses ValueError so callers
    that predate the typed error keep working."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz can't round-trip ml_dtypes (bfloat16 etc.) — store fp32;
            # restore casts back via the template dtype (exact for bf16)
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(ckpt_dir: str, step: int, tree, meta: Optional[Dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:09d}"
    final = os.path.join(ckpt_dir, name)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{name}_")
    try:
        arrays = _flatten(jax.device_get(tree))
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    _cleanup(ckpt_dir, keep_last)
    return final


def _cleanup(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(path):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None) -> Tuple[int, Any]:
    """Load into the template's structure. `shardings`: optional pytree of
    jax.sharding.Sharding matching template — arrays are device_put with
    them (elastic reshard to the current mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    leaves = []
    for (pth, leaf), shd in zip(flat, shard_flat):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(
            p, "name", p)))) for p in pth)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        tgt = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = jax.numpy.asarray(arr).astype(tgt)   # jnp casts to bf16 etc.
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(arr)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Template-free pytree artifacts (compressed-checkpoint serving)
# ---------------------------------------------------------------------------
# ``save``/``restore`` above need a same-structure template on load — fine
# for TrainState, impossible for a D-Rank compressed model, whose list-form
# tree (per-layer ranks differ) only exists AFTER compression. These
# functions persist the structure itself: the manifest records nested dict
# keys / list lengths / leaf dtypes, and leaves that are the same array
# object (cross-layer shared bases B) are stored once and re-aliased on
# load, so the artifact stays as small as the deduped param count.

def _encode_pytree(tree):
    arrays: Dict[str, np.ndarray] = {}
    seen: Dict[int, str] = {}

    def walk(node, path):
        if isinstance(node, dict):
            return {"kind": "dict",
                    "items": {k: walk(v, path + (str(k),))
                              for k, v in node.items()}}
        if isinstance(node, (list, tuple)):
            return {"kind": "list" if isinstance(node, list) else "tuple",
                    "items": [walk(v, path + (str(i),))
                              for i, v in enumerate(node)]}
        if not hasattr(node, "shape"):
            raise TypeError(f"non-array leaf at {'/'.join(path)}: "
                            f"{type(node).__name__}")
        key = _SEP.join(path)
        spec = {"kind": "leaf", "key": key, "dtype": str(node.dtype)}
        if id(node) in seen:
            spec["alias"] = seen[id(node)]
            return spec
        seen[id(node)] = key
        arr = np.asarray(jax.device_get(node))
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)     # npz-safe; manifest keeps dtype
        arrays[key] = arr
        return spec

    return walk(tree, ()), arrays


def _content_hash(arr: np.ndarray) -> str:
    """Content hash of one stored array: dtype + shape + raw bytes, so a
    silent bit flip, truncation, or shape rewrite all change the digest."""
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def save_pytree(ckpt_dir: str, tree, meta: Optional[Dict] = None,
                name: str = "pytree") -> str:
    """Atomic template-free save of an arbitrary dict/list pytree of arrays
    to ``<ckpt_dir>/<name>/``. Returns the artifact path. The manifest
    records a sha256 content hash per stored array; ``load_pytree
    (verify=True)`` (and ``launch/serve.py --verify``) re-checks them at
    boot."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, name)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_{name}_")
    try:
        structure, arrays = _encode_pytree(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "format": "pytree_v1",
            "time": time.time(),
            "structure": structure,
            "hashes": {k: _content_hash(v) for k, v in arrays.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def load_pytree(ckpt_dir: str, name: str = "pytree",
                verify: bool = False) -> Tuple[Any, Dict]:
    """Inverse of ``save_pytree``: returns ``(tree, meta)``. Aliased leaves
    come back as the SAME jax array object (shared-basis dedup survives
    the round trip). ``verify=True`` re-hashes every stored array against
    the manifest's content hashes and raises ``ValueError`` on any
    mismatch (or if the artifact predates hashing)."""
    path = os.path.join(ckpt_dir, name)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != "pytree_v1":
        raise ValueError(f"{path}: not a pytree_v1 artifact")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if verify:
        hashes = manifest.get("hashes")
        if not hashes:
            raise ValueError(
                f"{path}: artifact has no content hashes (saved before "
                f"integrity hashing); re-save to enable --verify")
        bad = sorted(k for k in hashes
                     if k not in arrays
                     or _content_hash(arrays[k]) != hashes[k])
        extra = sorted(set(arrays) - set(hashes))
        if bad or extra:
            raise IntegrityError(
                f"{path}: artifact integrity check failed — "
                f"corrupt/missing arrays {bad[:4]}"
                + (f", unmanifested arrays {extra[:4]}" if extra else ""))
    cache: Dict[str, jax.Array] = {}

    def build(spec):
        kind = spec["kind"]
        if kind == "dict":
            return {k: build(v) for k, v in spec["items"].items()}
        if kind in ("list", "tuple"):
            seq = [build(v) for v in spec["items"]]
            return seq if kind == "list" else tuple(seq)
        key = spec.get("alias", spec["key"])
        if key not in cache:
            if key not in arrays:
                raise KeyError(f"artifact missing array {key}")
            cache[key] = jax.numpy.asarray(arrays[key]).astype(spec["dtype"])
        return cache[key]

    return build(manifest["structure"]), manifest["meta"]


def artifact_fingerprint(ckpt_dir: str, name: str = "pytree") -> str:
    """Stable identity of a saved pytree artifact: sha256 over the
    manifest's per-array content hashes (falling back to the raw
    manifest bytes for pre-hashing artifacts). Two artifacts with
    byte-identical arrays fingerprint identically; any content change —
    re-save, bit flip, different ranks — changes it. The serve AOT
    compilation cache keys on this (``serve/aot.py``), so a compiled
    executable can never be replayed against a different artifact."""
    path = os.path.join(ckpt_dir, name)
    with open(os.path.join(path, "manifest.json"), "rb") as f:
        raw = f.read()
    manifest = json.loads(raw)
    h = hashlib.sha256()
    hashes = manifest.get("hashes")
    if hashes:
        for k in sorted(hashes):
            h.update(k.encode())
            h.update(hashes[k].encode())
    else:
        h.update(raw)
    return h.hexdigest()


def quarantine_artifact(ckpt_dir: str, name: str = "pytree") -> str:
    """Move a failing artifact aside so nothing boots from it again and a
    re-push/re-save can land cleanly at the original path. Returns the
    quarantine path (``<name>.quarantined[-N]``, first free suffix)."""
    src = os.path.join(ckpt_dir, name)
    dst = src + ".quarantined"
    n = 0
    while os.path.exists(dst):
        n += 1
        dst = f"{src}.quarantined-{n}"
    os.rename(src, dst)
    return dst


def load_pytree_resilient(ckpt_dir: str, name: str = "pytree",
                          verify: bool = True, retries: int = 2,
                          backoff_s: float = 0.05,
                          quarantine: bool = True) -> Tuple[Any, Dict]:
    """``load_pytree`` with retry-with-backoff and poison quarantine.

    Transient failures (a reader racing an atomic re-save, NFS hiccups)
    heal on retry; persistent ones (bit flips, truncation — anything the
    sha256 manifest check or the zip layer rejects) do not. After
    ``retries`` failed re-reads the artifact directory is moved to
    ``<name>.quarantined`` (unless ``quarantine=False``) and the last
    ``IntegrityError`` is raised — a supervisor loop never boot-loops on
    a poisoned artifact, and the quarantined bytes stay on disk for
    forensics."""
    import zipfile
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        # a missing artifact is a config error, not corruption: no retry,
        # no quarantine, and the caller sees the standard exception
        raise FileNotFoundError(
            f"no artifact directory {os.path.join(ckpt_dir, name)}")
    last: Optional[BaseException] = None
    for attempt in range(retries + 1):
        if attempt:
            time.sleep(backoff_s * (2 ** (attempt - 1)))
        try:
            return load_pytree(ckpt_dir, name=name, verify=verify)
        except (IntegrityError, OSError, zipfile.BadZipFile,
                json.JSONDecodeError, KeyError) as e:
            last = e
    where = os.path.join(ckpt_dir, name)
    if quarantine and os.path.exists(where):
        where = quarantine_artifact(ckpt_dir, name)
    raise IntegrityError(
        f"artifact {os.path.join(ckpt_dir, name)} failed to load after "
        f"{retries + 1} attempts"
        + (f"; quarantined at {where}" if quarantine else "")
        + f" — last error: {last}") from last


class AsyncCheckpointer:
    """Single worker thread; the newest pending save wins (drop stale)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, meta = item
            try:
                save(self.ckpt_dir, step, host_tree, meta, self.keep_last)
            except BaseException as e:          # surfaced on next submit
                self._err = e

    def submit(self, step: int, tree, meta: Optional[Dict] = None) -> None:
        if self._err:
            raise self._err
        host = jax.device_get(tree)              # sync host copy
        try:                                     # drop an unstarted stale save
            self._q.get_nowait()
        except queue.Empty:
            pass
        self._q.put((step, host, meta))

    def close(self, timeout: float = 60.0) -> None:
        self._q.put(None)
        self._thread.join(timeout=timeout)
        if self._err:
            raise self._err
