"""Version shims for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed ``CompilerParams`` in newer jax;
this container pins the older spelling. Resolve once here so every kernel
module stays written against the current name."""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))
assert CompilerParams is not None, "no Pallas TPU compiler-params class"
