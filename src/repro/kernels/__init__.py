"""Pallas TPU kernels for the compression-critical hot spots:
lowrank_matmul (fused x·B·C — the D-Rank deploy form), flash_attention
(online-softmax, GQA, causal/window), gram (blocked XᵀX for calibration).
`ops` holds the jit'd public wrappers; `ref` the pure-jnp oracles the
interpret-mode tests assert against."""
from repro.kernels import ops, ref  # noqa: F401
