"""Pallas TPU kernels for the compression-critical hot spots:
lowrank_matmul / lowrank_gemv (fused x·B·C — the D-Rank deploy form, at
prefill and decode shapes), flash_attention (online-softmax, GQA,
causal/window), decode_attention (ragged single-token serving loop with
length-bounded cache-block skipping), gram (blocked XᵀX for calibration).
`ops` holds the jit'd public wrappers; `ref` the pure-jnp oracles the
interpret-mode tests assert against. See DESIGN.md §3."""
from repro.kernels import ops, ref  # noqa: F401
