"""Pure-jnp oracles for every Pallas kernel. The interpret-mode kernels are
asserted allclose against these across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def lowrank_matmul(x: jax.Array, B: jax.Array, C: jax.Array) -> jax.Array:
    """y = (x @ B) @ C.  x: (..., K); B: (K, R); C: (R, N)."""
    t = x.astype(jnp.float32) @ B.astype(jnp.float32)
    return (t @ C.astype(jnp.float32)).astype(x.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd); GQA via H = KV*G.
    Returns (B, S, H, hd)."""
    Bb, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(Bb, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg,
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(Bb, S, H, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, window: int = 0,
                     softcap: float = 0.0) -> jax.Array:
    """Ragged single-token decode attention, dense-mask formulation — the
    oracle for kernels/decode_attention.py and numerically the same thing
    models/attention.attend_decode computes on the jnp path.

    q: (B, H, hd) one query per sequence; k/v: (B, L, KV, hd) cache pool;
    lengths: (B,) int32 = pos + 1 (0 marks a dead/purged slot whose output
    row is exact zeros — softmax over an all-masked row would otherwise
    emit uniform junk). window > 0 = ring-buffer layout (ring size window;
    slots >= window are alignment padding).
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    slot = jnp.arange(L)[None, :]                       # (1, L)
    pos = (lengths - 1)[:, None]
    if window:
        age = jnp.mod(pos - slot, window)
        valid = (age < jnp.minimum(pos + 1, window)) & (slot < window)
    else:
        valid = slot <= pos
    qg = q.reshape(B, 1, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    out = jnp.where((lengths > 0)[:, None, None, None, None], out, 0.0)
    return out.reshape(B, H, hd).astype(q.dtype)


def gram(x: jax.Array) -> jax.Array:
    """G = XᵀX with fp32 accumulation. x: (N, D) -> (D, D) fp32."""
    xf = x.astype(jnp.float32)
    return xf.T @ xf
