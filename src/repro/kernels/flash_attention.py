"""Flash attention (online-softmax) Pallas kernel: causal, sliding-window,
logit-softcap, GQA — the prefill/serve hot spot.

TPU adaptation: grid (batch·q_heads, q-blocks, kv-blocks) with the kv step
innermost ("arbitrary"); per-(head, q-block) running max/denominator/accum
live in VMEM scratch across kv steps. GQA never materializes repeated K/V —
the kv BlockSpec index map folds the q-head → kv-head mapping (h // group)
into the block index, so HBM reads stay at kv-head width.

Window/causal masking is positional per tile; fully-masked tiles are still
visited (grid is static) but their exp() work is zeroed — block-level
skipping is a §Perf iteration knob (see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(nkv: int, bq: int, bk: int, scale: float, causal: bool,
            window: int, softcap: float, kv_len: int,
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len:
        # ragged key axis: columns past the real T are alignment padding
        mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # masked -> ~0
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       heads: int, kv_heads: int, causal: bool = True,
                       window: int = 0, softcap: float = 0.0,
                       bq: int = 128, bk: int = 128, kv_len: int = 0,
                       interpret: bool = False) -> jax.Array:
    """q: (B·H, S, hd); k/v: (B·KV, T, hd). q row b·H + h attends kv row
    b·KV + h // (H/KV) — the GQA fold lives in the kv index map, so repeated
    K/V are never materialized. kv_len > 0 marks key columns >= kv_len as
    alignment padding (masked in-kernel), which keeps ragged non-causal
    shapes on the kernel path. Returns (B·H, S, hd)."""
    BH, S, hd = q.shape
    BKV, T, _ = k.shape
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    assert BH // heads == BKV // kv_heads, (BH, heads, BKV, kv_heads)
    nq = S // bq
    nkv = T // bk
    scale = hd ** -0.5
    G = heads // kv_heads

    def kv_index(bh, qi, ki):
        b = bh // heads
        h = bh % heads
        return (b * kv_heads + h // G, ki, 0)

    return pl.pallas_call(
        functools.partial(_kernel, nkv, bq, bk, scale, causal, window,
                          softcap, kv_len),
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # denominator
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
