"""Ragged single-token decode attention over the KV cache pool — the
serving-loop hot spot (DESIGN.md §3.4).

The decode step attends ONE new query per sequence against that sequence's
live cache prefix. The jnp reference path scores the entire (B, L) cache
with a dense fp32 mask every step; at serving shapes (L = max_len, most
slots short) nearly all of that work is masked out. This kernel instead:

  * takes a per-slot length vector (B,) as a SCALAR-PREFETCH operand, so
    block index maps can see it before the body runs;
  * clamps the kv block index to the slot's live prefix — grid steps past
    ``ceil(len/bk)`` re-address the previous block, and Pallas skips the
    DMA for an unchanged block index, so dead cache blocks never leave HBM
    (the compute for those steps is skipped with ``pl.when``);
  * handles both cache layouts: full (slot s holds position s; valid iff
    s < len) and ring buffer (slot s holds the latest position p ≡ s mod
    window; valid iff (pos - s) mod window < min(len, window));
  * is GQA-aware: grid dim 1 walks kv heads, each step scoring all G
    grouped q-heads against one kv head — repeated K/V never materialize;
  * accumulates in fp32 with the online-softmax recurrence (running max m,
    denominator l, accumulator acc in VMEM scratch across kv steps).

VMEM budget per step (bf16 cache, fp32 acc), bk=128: k/v tiles
2·128·hd·2 B (hd=128 → 64 KiB), q tile G·hd·2 B, scratch (2·G + G·hd)·4 B
— negligible against the 16 MiB budget; the kernel is DMA-bound, which is
exactly why block skipping is the win."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(nkv: int, bk: int, scale: float, window: int, softcap: float,
            len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    ln = len_ref[b]                                    # pos + 1; 0 = dead slot

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if window:
        # ring: every block may hold live slots — except a dead slot
        # (ln == 0, e.g. freshly purged), which must emit exact zeros
        # rather than softmax over an all-masked row
        bound = jnp.where(ln > 0, nkv, 0)
    else:
        bound = (ln + bk - 1) // bk    # full cache: live prefix only (0 dead)

    @pl.when(ki < bound)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        slot = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        if window:
            # ring layout: slot s holds position pos - ((pos - s) mod w)
            age = jnp.mod(ln - 1 - slot, window)
            valid = (age < jnp.minimum(ln, window)) & (slot < window)
        else:
            valid = slot < ln
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, :, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_bkgh(q: jax.Array, k: jax.Array, v: jax.Array,
                          lengths: jax.Array, *, window: int = 0,
                          softcap: float = 0.0, bk: int = 128,
                          interpret: bool = False) -> jax.Array:
    """q: (B, KV, G, hd) one token per sequence; k/v: (B, L, KV, hd) cache
    pool (L a multiple of bk — the ops wrapper pads); lengths: (B,) int32 =
    pos + 1 per slot (0 marks a dead/purged slot, whose output row is exact
    zeros). window > 0 selects the ring-buffer layout (real ring size =
    window; L may carry alignment padding past it).
    Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    L = k.shape[1]
    assert L % bk == 0, (L, bk)
    assert lengths.shape == (B,) and lengths.dtype == jnp.int32
    nkv = L // bk
    scale = hd ** -0.5

    def kv_index(b, h, ki, len_ref):
        if window:
            return (b, ki, h, 0)
        # clamp to the live prefix; the outer max guards length-0 slots
        # (freshly purged), whose nb - 1 would otherwise address block -1
        nb = (len_ref[b] + bk - 1) // bk
        return (b, jnp.maximum(jnp.minimum(ki, nb - 1), 0), h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ki, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max
            pltpu.VMEM((G, 1), jnp.float32),     # denominator
            pltpu.VMEM((G, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, nkv, bk, scale, window, softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(lengths, q, k, v)


def _paged_kernel(nb: int, bk: int, scale: float, softcap: float,
                  len_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref):
    """Same online-softmax recurrence as ``_kernel``'s full-cache path; the
    kv tile for logical block ki arrives via the block-table indirection in
    the index map, so the math here is bit-identical to the contiguous
    kernel given the same token values."""
    b = pl.program_id(0)
    ki = pl.program_id(2)
    ln = len_ref[b]                                    # pos + 1; 0 = dead slot

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bound = (ln + bk - 1) // bk        # live logical blocks (0 for dead slots)

    @pl.when(ki < bound)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        slot = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
        valid = slot < ln
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, :, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_paged_bkgh(q: jax.Array, k: jax.Array, v: jax.Array,
                                lengths: jax.Array, table: jax.Array, *,
                                softcap: float = 0.0,
                                interpret: bool = False) -> jax.Array:
    """Block-table paged variant of :func:`decode_attention_bkgh` (full
    cache layout only — ring/window stays contiguous).

    q: (B, KV, G, hd); k/v: (P, bk, KV, hd) — one flat arena of P physical
    blocks shared by every slot, block 0 reserved as the never-written null
    block; lengths: (B,) int32 = pos + 1 (0 = dead slot, exact-zero output);
    table: (B, NB) int32 — logical block j of slot b lives in physical
    block table[b, j].

    Both the lengths AND the table ride as scalar-prefetch operands, so the
    kv index map resolves the indirection before the body runs: grid step
    ki of slot b DMAs arena block table[b, clamp(ki)]. Steps past the live
    prefix re-address the previous physical block — Pallas skips the DMA
    for an unchanged index, exactly like the contiguous clamp — and their
    compute is skipped with ``pl.when``. Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    P, bk = k.shape[0], k.shape[1]
    NB = table.shape[1]
    assert table.shape == (B, NB) and table.dtype == jnp.int32, table
    assert lengths.shape == (B,) and lengths.dtype == jnp.int32
    scale = hd ** -0.5

    def kv_index(b, h, ki, len_ref, tbl_ref):
        nb_live = (len_ref[b] + bk - 1) // bk
        j = jnp.maximum(jnp.minimum(ki, nb_live - 1), 0)
        return (tbl_ref[b, j], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, NB),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, ki, len_ref, tbl_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
            pl.BlockSpec((1, bk, 1, hd), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, ki, len_ref, tbl_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max
            pltpu.VMEM((G, 1), jnp.float32),     # denominator
            pltpu.VMEM((G, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, NB, bk, scale, softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(lengths, table, q, k, v)
