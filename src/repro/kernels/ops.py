"""Public jit'd wrappers around the Pallas kernels.

Responsibilities: shape normalization (leading-dim flattening, padding to
block multiples), block-size selection under the VMEM budget, interpret-mode
fallback on CPU (correctness validation — this container has no TPU), and
custom_vjp so compressed models remain trainable (backward falls back to
the jnp reference formulation; forward-path fusion is the deploy win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_bkgh,
                                            decode_attention_paged_bkgh)
from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.gram import gram_blocked
from repro.kernels.lowrank_matmul import lowrank_gemv, lowrank_matmul_2d

# At or below this many flattened rows the low-rank matmul is decode-shaped:
# route to the weight-streaming GEMV kernel instead of the prefill tiler.
GEMV_MAX_ROWS = 64


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


# ---------------------------------------------------------------------------
# lowrank_matmul: y = (x @ B) @ C
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=())
def lowrank_matmul(x: jax.Array, B: jax.Array, C: jax.Array) -> jax.Array:
    return _lowrank_fwd_impl(x, B, C)


def _lowrank_fwd_impl(x, B, C):
    *lead, K = x.shape
    N = C.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    if M <= GEMV_MAX_ROWS:
        # decode shape: pad rows to the 8-sublane only and K/N to 128 —
        # the batch never fills an MXU tile, and tighter alignment keeps
        # zero padding out of the weight stream (the decode bottleneck).
        Mp = _round_up(M, 8)
        Kp = _round_up(K, 128)
        Np = _round_up(N, 128)
        bk = Kp if Kp <= 512 else 128
        bn = Np if Np <= 512 else 128
        xp = _pad_to(_pad_to(x2, 0, Mp), 1, bk)
        y = lowrank_gemv(xp, _pad_to(B.astype(x.dtype), 0, bk),
                         _pad_to(C.astype(x.dtype), 1, bn),
                         bk=bk, bn=bn, interpret=not _on_tpu())
        return y[:M, :N].reshape(*lead, N)
    bm = 128
    bk = min(512, _round_up(K, 128))
    bn = min(512, _round_up(N, 128))
    xp = _pad_to(_pad_to(x2, 0, bm), 1, bk)
    Bp = _pad_to(B.astype(x.dtype), 0, bk)
    Cp = _pad_to(C.astype(x.dtype), 1, bn)
    y = lowrank_matmul_2d(xp, Bp, Cp, bm=bm, bk=bk, bn=bn,
                          interpret=not _on_tpu())
    return y[:M, :N].reshape(*lead, N)


def _lowrank_fwd(x, B, C):
    return _lowrank_fwd_impl(x, B, C), (x, B, C)


def _lowrank_bwd(res, g):
    x, B, C = res
    gf = g.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    t = xf @ B.astype(jnp.float32)                       # (..., R)
    x2 = xf.reshape(-1, x.shape[-1])
    t2 = t.reshape(-1, t.shape[-1])
    g2 = gf.reshape(-1, g.shape[-1])
    dC = (t2.T @ g2).astype(C.dtype)
    gt = g2 @ C.astype(jnp.float32).T                    # (M, R)
    dB = (x2.T @ gt).astype(B.dtype)
    dx = (gt @ B.astype(jnp.float32).T).reshape(x.shape).astype(x.dtype)
    return dx, dB, dC


lowrank_matmul.defvjp(_lowrank_fwd, _lowrank_bwd)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) -> (B, S, H, hd)."""
    return _flash_fwd_impl(q, k, v, causal, window, softcap)


def _flash_fwd_impl(q, k, v, causal, window, softcap):
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    bq = min(128, _round_up(S, 8))
    bk = min(128, _round_up(T, 8))
    Sp, Tp = _round_up(S, bq), _round_up(T, bk)
    qb = _pad_to(q.transpose(0, 2, 1, 3).reshape(B * H, S, hd), 1, bq)
    kb = _pad_to(k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd), 1, bk)
    vb = _pad_to(v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd), 1, bk)
    # padded kv columns must never win the max: the kernel masks kpos >=
    # kv_len explicitly, so ragged non-causal shapes stay on the kernel
    # path (causal already kills padded kpos for every real q row).
    o = flash_attention_bh(qb, kb, vb, heads=H, kv_heads=KV,
                           causal=causal, window=window, bq=bq, bk=bk,
                           softcap=softcap, kv_len=T if Tp != T else 0,
                           interpret=not _on_tpu())
    o = o[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return o


def _flash_fwd(q, k, v, causal, window, softcap):
    return _flash_fwd_impl(q, k, v, causal, window, softcap), (q, k, v)


def _flash_bwd(causal, window, softcap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention(
            q_, k_, v_, causal=causal, window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# decode attention (single new token vs. the ragged KV cache pool)
# ---------------------------------------------------------------------------
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array, *, window: int = 0,
                     softcap: float = 0.0) -> jax.Array:
    """q: (B, H, hd) — one new token per sequence; k/v: (B, L, KV, hd)
    cache pool; lengths: (B,) int32 per-slot live length (pos + 1).
    window > 0 = ring-buffer cache layout. Returns (B, H, hd).

    Pads the cache length to a block multiple (padded slots are masked
    in-kernel) — never transposes or copies the pool itself. Inference-
    only: no vjp (the decode step is never differentiated)."""
    B, H, hd = q.shape
    L, KV = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(128, _round_up(L, 8))
    if L % bk:
        k = _pad_to(k, 1, bk)
        v = _pad_to(v, 1, bk)
    o = decode_attention_bkgh(
        q.reshape(B, KV, G, hd), k, v, lengths.astype(jnp.int32),
        window=window, softcap=softcap, bk=bk, interpret=not _on_tpu())
    return o.reshape(B, H, hd)


def decode_attention_paged(q: jax.Array, k: jax.Array, v: jax.Array,
                           lengths: jax.Array, table: jax.Array, *,
                           softcap: float = 0.0) -> jax.Array:
    """Paged-pool decode attention. q: (B, H, hd); k/v: (P, bk, KV, hd)
    flat block arena (block 0 = reserved null block); lengths: (B,) live
    length per slot (pos + 1; 0 = dead slot → exact-zero row); table:
    (B, NB) int32 block table mapping logical block j of slot b to its
    physical arena block. The block size is fixed by the arena layout
    (serve.api validates it against the TPU sublane multiple), so unlike
    the contiguous wrapper there is nothing to pad here. Returns
    (B, H, hd). Inference-only, full-cache layout only."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    o = decode_attention_paged_bkgh(
        q.reshape(B, KV, G, hd), k, v, lengths.astype(jnp.int32),
        table.astype(jnp.int32), softcap=softcap,
        interpret=not _on_tpu())
    return o.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------
def gram(x: jax.Array) -> jax.Array:
    """x: (..., D) -> (D, D) fp32 Gram accumulated over all leading dims."""
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    bi = bj = min(256, _round_up(D, 128))
    bn = min(512, _round_up(x2.shape[0], 8))
    xp = _pad_to(_pad_to(x2, 0, bn), 1, bi)
    g = gram_blocked(xp, bi=bi, bj=bj, bn=bn, interpret=not _on_tpu())
    return g[:D, :D]
