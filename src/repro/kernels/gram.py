"""Blocked Gram accumulation kernel: G = XᵀX over calibration tokens —
the compression pipeline's device-side hot spot (DESIGN.md §3).

On TPU this is an MXU contraction over the token axis with fp32
accumulation; grid (D/bi, D/bj, N/bn) with the token step innermost and the
(bi × bj) output tile resident in VMEM across token steps. The paper's fp64
S-matrix precision is preserved by accumulating per-shard fp32 partials
that the host driver sums in fp64 (numpy) before the Cholesky.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(nn: int, xi_ref, xj_ref, g_ref, acc_ref):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xi_ref[...], xj_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(n == nn - 1)
    def _emit():
        g_ref[...] = acc_ref[...]


def gram_blocked(x: jax.Array, *, bi: int = 256, bj: int = 256,
                 bn: int = 512, interpret: bool = False) -> jax.Array:
    """x: (N, D) -> G (D, D) fp32. N, D must divide (wrapper pads)."""
    N, D = x.shape
    assert N % bn == 0 and D % bi == 0 and D % bj == 0, (N, D, bi, bj, bn)
    nn = N // bn
    return pl.pallas_call(
        functools.partial(_kernel, nn),
        grid=(D // bi, D // bj, nn),
        in_specs=[
            pl.BlockSpec((bn, bi), lambda i, j, n: (n, i)),
            pl.BlockSpec((bn, bj), lambda i, j, n: (n, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((D, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, x)
