"""Fused low-rank matmul kernel: y = (x @ B) @ C without the rank-k
intermediate touching HBM — the deploy-time hot spot of every D-Rank
compressed linear (DESIGN.md §3).

Why fusion matters: the factorized pair reads (K·R + R·N) weight bytes
instead of K·N, but an unfused implementation round-trips t = x·B
(M·R values) through HBM twice. At training/prefill token counts M is
large, so the round-trip rivals the weight traffic — fusing keeps t in a
VMEM scratch accumulator.

Structure — a PHASED grid over (m-blocks, k-steps + n-steps):
  phase 1 (s < nk):   t[bm, R] += x[bm, bk] @ B[bk, R]      (MXU, fp32 acc)
  phase 2 (s >= nk):  y[bm, bn] = t[bm, R] @ C[R, bn]
Block index maps clamp into the valid range per phase so each step streams
exactly one (bm×bk) x-tile + (bk×R) B-tile, or one (R×bn) C-tile. All tile
dims are rounded to MXU lane/sublane multiples by the ops wrapper.

VMEM budget per step (bf16 in, fp32 acc), defaults bm=128 bk=512 bn=512:
  x tile 128·512·2 = 128 KiB, B tile 512·R·2 (R≤2048 → ≤2 MiB),
  C tile R·512·2 ≤ 2 MiB, t scratch 128·R·4 ≤ 1 MiB, y 128·512·2 = 128 KiB
  — comfortably inside a 16 MiB VMEM with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(nk: int, x_ref, b_ref, c_ref, y_ref, t_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(s < nk)
    def _accumulate():
        t_ref[...] += jnp.dot(x_ref[...], b_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(s >= nk)
    def _emit():
        y_ref[...] = jnp.dot(t_ref[...].astype(c_ref.dtype), c_ref[...],
                             preferred_element_type=jnp.float32
                             ).astype(y_ref.dtype)


def _gemv_kernel(nk: int, x_ref, b_ref, c_ref, y_ref, t_ref):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(s < nk)
    def _accumulate():
        t_ref[...] += jnp.dot(x_ref[...], b_ref[...],
                              preferred_element_type=jnp.float32)

    @pl.when(s >= nk)
    def _emit():
        y_ref[...] = jnp.dot(t_ref[...].astype(c_ref.dtype), c_ref[...],
                             preferred_element_type=jnp.float32
                             ).astype(y_ref.dtype)


def lowrank_gemv(x: jax.Array, B: jax.Array, C: jax.Array, *,
                 bk: int = 512, bn: int = 512,
                 interpret: bool = False) -> jax.Array:
    """Decode-shaped fused low-rank matmul: y = (x @ B) @ C for SMALL M
    (M = decode batch, ≤ 64 rows after the ops wrapper pads to a sublane
    multiple of 8 — never a full 128 MXU tile).

    1-D grid (k-steps then n-steps), single m-block: each step streams
    exactly one (M×bk) x-tile + (bk×R) B-tile, or one (R×bn) C-tile; the
    rank-R intermediate lives in a (M×R) fp32 scratch. Every activation
    and weight byte is read exactly once — decode is weight-bandwidth-
    bound, so the wrapper aligns K/N to 128 (not the prefill kernel's 512)
    to keep zero-padding traffic off the ragged shapes the compressor
    emits, and pads M only to the 8-row sublane, never a 128 MXU tile.

    VMEM: x M·K·2 B (M≤64, K≤16384 → ≤2 MiB), B tile bk·R·2, C tile
    R·bn·2, t M·R·4 — inside budget with double buffering at defaults."""
    M, K = x.shape
    R = B.shape[1]
    N = C.shape[1]
    assert M <= 64 and K % bk == 0 and N % bn == 0, (M, K, N, bk, bn)
    nk = K // bk
    nn = N // bn
    grid = (nk + nn,)

    return pl.pallas_call(
        functools.partial(_gemv_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, bk), lambda s: (0, jnp.minimum(s, nk - 1))),
            pl.BlockSpec((bk, R), lambda s: (jnp.minimum(s, nk - 1), 0)),
            pl.BlockSpec((R, bn), lambda s: (0, jnp.maximum(s - nk, 0))),
        ],
        out_specs=pl.BlockSpec((M, bn), lambda s: (0, jnp.maximum(s - nk, 0))),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((M, R), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
    )(x, B, C)


def lowrank_matmul_2d(x: jax.Array, B: jax.Array, C: jax.Array, *,
                      bm: int = 128, bk: int = 512, bn: int = 512,
                      interpret: bool = False) -> jax.Array:
    """x: (M, K); B: (K, R); C: (R, N) -> (M, N). M/K/N must divide by the
    block sizes (the ops wrapper pads); R rides whole in VMEM."""
    M, K = x.shape
    R = B.shape[1]
    N = C.shape[1]
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N, bm, bk, bn)
    nk = K // bk
    nn = N // bn
    grid = (M // bm, nk + nn)

    return pl.pallas_call(
        functools.partial(_kernel, nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, s: (i, jnp.minimum(s, nk - 1))),
            pl.BlockSpec((bk, R), lambda i, s: (jnp.minimum(s, nk - 1), 0)),
            pl.BlockSpec((R, bn), lambda i, s: (0, jnp.maximum(s - nk, 0))),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda i, s: (i, jnp.maximum(s - nk, 0))),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, R), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, B, C)
