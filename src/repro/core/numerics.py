"""Whitening + effective-rank numerics (host-side, fp64).

The paper (following SVD-LLM / Basis Sharing) whitens each weight with the
Cholesky factor of the calibration Gram matrix: with ``G = XᵀX = L Lᵀ``,
``‖X·ΔW‖²_F = ‖Lᵀ·ΔW‖²_F``, so the Eckart–Young-optimal activation-aware
rank-k approximation is the truncated SVD of ``S·W`` with ``S = Lᵀ``,
reconstructed as ``W ≈ S⁻¹ (U_k Σ_k) V_kᵀ = B C``.

All of this runs in numpy float64 on host — TPUs have no fp64, and the
paper explicitly keeps S in fp64 (DESIGN.md §7.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class Whitener:
    """Either a triangular matrix pair or a diagonal scale vector."""
    S: Optional[np.ndarray] = None          # (d, d) upper triangular
    S_inv: Optional[np.ndarray] = None      # (d, d)
    diag: Optional[np.ndarray] = None       # (d,) positive scale

    def apply(self, W: np.ndarray) -> np.ndarray:
        if self.diag is not None:
            return self.diag[:, None] * W
        if self.S is not None:
            return self.S @ W
        return W

    def unapply_basis(self, B: np.ndarray) -> np.ndarray:
        """Map a basis of the whitened space back: B_orig = S⁻¹ B."""
        if self.diag is not None:
            return B / self.diag[:, None]
        if self.S is not None:
            return self.S_inv @ B
        return B


def cholesky_whitener(G: np.ndarray, damp: float = 1e-6) -> Whitener:
    """G: (d, d) fp64 Gram. Damped for rank-deficient calibration sets;
    escalates damping ×10 until the factorization succeeds."""
    d = G.shape[0]
    if not np.isfinite(G).all():
        # LAPACK may "succeed" on NaN/inf input and hand back a NaN
        # factor; fail loudly instead of whitening with garbage
        raise np.linalg.LinAlgError(
            "cholesky whitener got a non-finite Gram; "
            + _gram_condition_report(G))
    G = 0.5 * (G + G.T)
    tau = damp * max(np.trace(G) / d, 1e-12)
    eye = np.eye(d)
    taus = []
    for _ in range(12):
        taus.append(tau)
        try:
            L = np.linalg.cholesky(G + tau * eye)
            S = L.T                                  # upper triangular
            S_inv = np.linalg.solve(S, eye)          # triangular solve
            return Whitener(S=S, S_inv=S_inv)
        except np.linalg.LinAlgError:
            tau *= 10.0
    raise np.linalg.LinAlgError(
        f"cholesky failed after {len(taus)} damping escalations "
        f"(taus tried: {taus[0]:.3e} .. {taus[-1]:.3e}); "
        + _gram_condition_report(G))


def _gram_condition_report(G: np.ndarray) -> str:
    """Diagnostic tail for the escalation failure message: a condition
    estimate when the Gram is finite, the non-finite count when it isn't
    (the only way damping can fail 12 times)."""
    bad = int(np.size(G) - np.isfinite(G).sum())
    if bad:
        return f"Gram has {bad} non-finite entries"
    try:
        lam = np.linalg.eigvalsh(G)
        cond = abs(lam).max() / max(abs(lam).min(), 1e-300)
        return (f"Gram condition estimate {cond:.3e} "
                f"(eig range [{lam.min():.3e}, {lam.max():.3e}])")
    except np.linalg.LinAlgError:
        return "Gram condition estimate unavailable (eigvalsh failed)"


def whitener_from_factor(R: np.ndarray) -> Whitener:
    """Whitener from an upper-triangular factor with ``RᵀR = G`` — the
    streaming-whitening output (capture.StreamingCalibrator whiten_tags /
    numerics_jax.combine_factors), which never materializes G. QR sign
    ambiguity is fixed by making the diagonal positive; a tiny diagonal
    floor guards rank-deficient streams the way damping does for Grams."""
    R = np.asarray(R, dtype=np.float64)
    d = R.shape[0]
    s = np.sign(np.diag(R))
    s[s == 0] = 1.0
    S = s[:, None] * R
    floor = 1e-7 * max(np.abs(np.diag(S)).max(), 1e-30)
    dia = np.diag(S).copy()
    S[np.arange(d), np.arange(d)] = np.maximum(dia, floor)
    S_inv = np.linalg.solve(S, np.eye(d))
    return Whitener(S=S, S_inv=S_inv)


def diag_whitener(scale: np.ndarray, floor: float = 1e-8) -> Whitener:
    s = np.maximum(np.asarray(scale, dtype=np.float64), floor)
    return Whitener(diag=s)


def identity_whitener() -> Whitener:
    return Whitener()


# ---------------------------------------------------------------------------
# Effective rank (the paper's metric, §3.2.1)
# ---------------------------------------------------------------------------
def effective_rank(singular_values: np.ndarray, eps: float = 1e-12) -> float:
    """exp(Shannon entropy of the normalized squared singular values).

    Properties (tested): scale-invariant; 1 <= R_eff <= #nonzero σ; equals
    the count for a flat spectrum.
    """
    lam = np.asarray(singular_values, dtype=np.float64) ** 2
    total = lam.sum()
    if total <= eps:
        return 1.0
    p = lam / total
    p = p[p > eps]
    return float(np.exp(-(p * np.log(p)).sum()))


def whitened_svd(W_cat: np.ndarray, wh: Whitener
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """SVD of the whitened concatenated group matrix. Returns (U, σ, Vᵀ)."""
    M = wh.apply(np.asarray(W_cat, dtype=np.float64))
    return np.linalg.svd(M, full_matrices=False)


def truncate_factors(U: np.ndarray, sig: np.ndarray, Vt: np.ndarray, k: int,
                     wh: Whitener) -> Tuple[np.ndarray, np.ndarray]:
    """Rank-k factors in the ORIGINAL space: B (d1, k), C (k, n·d2),
    with W_cat ≈ B @ C."""
    B = wh.unapply_basis(U[:, :k] * sig[None, :k])
    return B, Vt[:k]
