"""Device-side compression math: batched whitening + whitened SVD + refine.

This is the jit-compiled counterpart of ``core.numerics`` (which stays the
host fp64 precision oracle; see tests/test_compress_device.py). Everything
here runs in fp32 — no fp64 anywhere, so the same code path compiles for
TPU — and is batched over a leading group axis so a whole bucket of
same-shaped matrices decomposes in ONE call instead of a host loop.

The decomposition avoids rectangular SVD entirely: with ``M = S·W_cat`` the
whitened factorization is recovered from the eigendecomposition of the
SMALL-side Gram,

    d1 <= n·d2 :  K = S (W Wᵀ) Sᵀ = M Mᵀ   (d1, d1)
                  B = S⁻¹ U_k Σ_k,   C = Σ_k⁻¹ U_kᵀ M = (S U_k)ᵀ W / σ
    d1 >  n·d2 :  K = Mᵀ M                  (n·d2, n·d2)
                  B = S⁻¹ M V_k = W V_k,    C = V_kᵀ

so the only cubic-cost op is a (min-side)² eigh while every large-dimension
contraction is a plain GEMM — the shape regime where the host fp64
rectangular SVD is slowest (wide shared-basis groups, fused MoE experts) is
exactly where this wins the most. The full singular spectrum (every nonzero
σ, identical in count to ``numpy.linalg.svd``) comes out of the same eigh,
so effective-rank allocation sees the same input as the oracle.

For very large min-sides the exact eigh itself dominates; ``rsvd > 0``
switches to a randomized range-finder (Halko et al.: Gaussian sketch +
subspace iterations + small eigh) that only pays GEMMs in the large
dimensions. Its top-(k+oversample) estimates are approximate, but the
truncated tail energy is restored exactly via the trace identity
(``_dec_rsvd``), so rank allocation sees a full-length spectrum with the
right total energy (DESIGN.md §1.5).

Structure note: the pipeline is deliberately split into SEVERAL small
jitted stages instead of one fused jit. XLA:CPU runs the dense dots in a
computation noticeably slower when the same executable also contains
LAPACK custom calls (cholesky/eigh/qr/trsm), so factorizations and GEMMs
live in separate executables; intermediates are jax arrays and never leave
the device, and each stage still batches the whole bucket.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

MAX_DAMP_TRIES = 12          # matches numerics.cholesky_whitener


# ---------------------------------------------------------------------------
# Whitening: batched Cholesky with per-matrix damping escalation
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("max_tries",))
def cholesky_escalate(G: jax.Array, damp: float = 1e-6,
                      max_tries: int = MAX_DAMP_TRIES
                      ) -> Tuple[jax.Array, jax.Array]:
    """Batched damped Cholesky ``L Lᵀ = G + τI`` with the same ×10
    escalation policy as the host oracle, vectorized per batch member:
    XLA's cholesky reports failure as NaNs, so members whose factorization
    failed get their τ bumped and re-factored while already-converged
    members keep their τ. Returns ``(L, tau)`` with L lower-triangular;
    a still-NaN L after ``max_tries`` means the Gram itself was non-finite
    (the caller's factors will surface it).
    """
    G = G.astype(jnp.float32)
    d = G.shape[-1]
    G = 0.5 * (G + jnp.swapaxes(G, -1, -2))
    eye = jnp.eye(d, dtype=G.dtype)
    tr = jnp.trace(G, axis1=-2, axis2=-1) / d
    tau0 = damp * jnp.maximum(tr, 1e-12)

    def factor(tau):
        return jnp.linalg.cholesky(G + tau[..., None, None] * eye)

    def ok(L):
        return jnp.isfinite(L).all(axis=(-2, -1))

    def cond(state):
        i, _tau, L = state
        return jnp.logical_and(i < max_tries, jnp.logical_not(ok(L).all()))

    def body(state):
        i, tau, L = state
        tau = jnp.where(ok(L), tau, tau * 10.0)
        return i + 1, tau, factor(tau)

    _, tau, L = jax.lax.while_loop(cond, body, (0, tau0, factor(tau0)))
    return L, tau


@jax.jit
def _fix_factor(R: jax.Array) -> jax.Array:
    """Normalize a streamed upper-triangular factor the way the host's
    ``numerics.whitener_from_factor`` does: fix the QR sign ambiguity by
    making the diagonal positive, and floor the diagonal so rank-deficient
    calibration streams (fewer rows than d) don't make the triangular
    solves blow up."""
    R = R.astype(jnp.float32)
    d = R.shape[-1]
    dia = jnp.diagonal(R, axis1=-2, axis2=-1)
    s = jnp.sign(dia)
    s = jnp.where(s == 0, 1.0, s)
    R = R * s[..., :, None]
    dia = jnp.abs(dia)
    floor = 1e-7 * jnp.maximum(dia.max(axis=-1, keepdims=True), 1e-30)
    return R + (jnp.maximum(dia, floor) - dia)[..., :, None] \
        * jnp.eye(d, dtype=jnp.float32)


@jax.jit
def combine_factors(Rs: jax.Array) -> jax.Array:
    """Merge per-member streaming-whitening factors into one group factor:
    ``Rs (b, n, d, d)`` with ``R_iᵀR_i = G_i`` → R with ``RᵀR = Σ_i G_i``,
    via the R of a QR over the stacked factors (no Gram is ever formed)."""
    b, n, d, _ = Rs.shape
    stacked = Rs.astype(jnp.float32).reshape(b, n * d, d)
    return jnp.linalg.qr(stacked, mode="r")


@jax.jit
def tree_reduce_factors(Rs: jax.Array) -> jax.Array:
    """Exact distributed-whitening reduction (DESIGN.md §1.6): merge
    per-shard streaming factors ``Rs (m, d, d)`` (R_iᵀR_i = G_i, one per
    data-parallel shard) into a single R with ``RᵀR = Σ_i G_i`` by
    PAIRWISE rounds ``R' = qr_r([R_a; R_b])``. Each round is an orthogonal
    transform of the stacked rows, so any reduction order yields the same
    RᵀR — the tree order keeps every QR at (2d, d), the per-hop shape a
    ring/tree reduction would run on a real mesh, and the result matches
    the single-shard QR chain up to fp rounding and row signs."""
    Rs = Rs.astype(jnp.float32)
    m = Rs.shape[0]
    while m > 1:
        half = m // 2
        pairs = jnp.concatenate([Rs[:half], Rs[half:2 * half]], axis=1)
        reduced = jnp.linalg.qr(pairs, mode="r")      # (half, d, d)
        if m % 2:
            reduced = jnp.concatenate([reduced, Rs[2 * half:]], axis=0)
        Rs = reduced
        m = Rs.shape[0]
    return Rs[0]


# ---------------------------------------------------------------------------
# Jitted stages (LAPACK ops and GEMMs deliberately in separate executables)
# ---------------------------------------------------------------------------
@jax.jit
def _eigh_desc(K: jax.Array) -> Tuple[jax.Array, jax.Array]:
    lam, V = jnp.linalg.eigh(K)
    return lam[..., ::-1], V[..., ::-1]


@jax.jit
def _nt_gram(X: jax.Array) -> jax.Array:
    """X Xᵀ batched, contraction over the (contiguous) last axis."""
    return jnp.einsum("bim,bjm->bij", X, X)


@jax.jit
def _sandwich(L: jax.Array, S: jax.Array) -> jax.Array:
    """Lᵀ S L (small square bmms)."""
    return jnp.einsum("bji,bjk->bik", L, jnp.einsum("bij,bjk->bik", S, L))


@jax.jit
def _tn_project(A: jax.Array, W: jax.Array) -> jax.Array:
    """Aᵀ W with A (b, d, k), W (b, d, m) → (b, k, m). The d-major layout
    of both operands is the fastest big-GEMM form XLA:CPU offers short of
    transposing W itself."""
    return jnp.einsum("bdk,bdm->bkm", A, W)


@jax.jit
def _solve_lower_t(L: jax.Array, Y: jax.Array) -> jax.Array:
    """L⁻ᵀ Y batched (L lower-triangular)."""
    return jax.vmap(lambda lo, y: jax.scipy.linalg.solve_triangular(
        lo, y, lower=True, trans=1))(L, Y)


@jax.jit
def _cho_solve(Lk: jax.Array, Y: jax.Array) -> jax.Array:
    """(Lk Lkᵀ)⁻¹ Y batched."""
    def one(lo, y):
        return jax.scipy.linalg.solve_triangular(
            lo, jax.scipy.linalg.solve_triangular(lo, y, lower=True),
            lower=True, trans=1)
    return jax.vmap(one)(Lk, Y)


@jax.jit
def _bmm(A: jax.Array, B: jax.Array) -> jax.Array:
    return jnp.einsum("bij,bjk->bik", A, B)


@jax.jit
def _qr_q(Y: jax.Array) -> jax.Array:
    return jnp.linalg.qr(Y)[0]


def _whiten_big(W, L, sL):
    """M = S W for the given whitener (None/None = identity)."""
    if L is not None:
        return _tn_project(L, W)             # Lᵀ W
    if sL is not None:
        return sL[:, :, None] * W
    return W


# ---------------------------------------------------------------------------
# Batched whitened decomposition
# ---------------------------------------------------------------------------
def _dec_left(W, L, sL, k):
    """d1 <= n·d2 case. Exactly one of L (cholesky lower factor) / sL
    (diag scale, (b, d1)) is given; both None means identity whitener."""
    if L is not None:
        K = _sandwich(L, _nt_gram(W))
    elif sL is not None:
        K = sL[:, :, None] * _nt_gram(W) * sL[:, None, :]
    else:
        K = _nt_gram(W)
    lam, U = _eigh_desc(K)
    sig = jnp.sqrt(jnp.clip(lam, 0.0))
    Uk = U[:, :, :k]
    sigk = sig[:, :k]
    inv_sig = (1.0 / jnp.maximum(sigk, 1e-20))[:, :, None]
    if L is not None:
        # C = (L Uk)ᵀ W / σ ; B = L⁻ᵀ (Uk Σ)  (S = Lᵀ ⇒ S⁻¹ = L⁻ᵀ)
        C = _tn_project(_bmm(L, Uk), W) * inv_sig
        B = _solve_lower_t(L, Uk * sigk[:, None, :])
    elif sL is not None:
        C = _tn_project(Uk * sL[:, :, None], W) * inv_sig
        B = (Uk * sigk[:, None, :]) / sL[:, :, None]
    else:
        C = _tn_project(Uk, W) * inv_sig
        B = Uk * sigk[:, None, :]
    return sig, B, C


def _dec_right(W, L, sL, k):
    """d1 > n·d2 case: eigh on the (n·d2)-side Gram. B = S⁻¹ M V_k = W V_k
    for ANY whitener, so no solve is needed."""
    M = _whiten_big(W, L, sL)
    K = _tn_project(M, M)
    lam, V = _eigh_desc(K)
    sig = jnp.sqrt(jnp.clip(lam, 0.0))
    Vk = V[:, :, :k]
    B = _bmm(W, Vk)
    C = jnp.swapaxes(Vk, 1, 2)
    return sig, B, C


def _tail_spectrum(sig_l: jax.Array, tail_energy: jax.Array,
                   n_tail: int) -> jax.Array:
    """Synthetic spectrum for the n_tail singular values an rsvd sketch
    never saw: geometric decay ``σ²_{l+j} = σ²_l ρ^j`` continuing from
    the last estimated value, with ρ bisected per batch member so the
    tail sums to the (exactly known) truncated energy, then renormalized
    so the energy identity holds to roundoff. Degenerate cases (σ_l = 0,
    ρ → 1, zero tail) all collapse to a flat tail with the right energy
    via the renormalization. The final clamp at σ²_l keeps the full
    spectrum NON-INCREASING (the allocators' ordering invariant) even
    when the truncated energy exceeds ``n_tail·σ²_l`` — i.e. when the
    sketch underestimated σ_l itself — at the cost of undercounting
    energy in exactly that saturated regime: ordering beats exactness
    there. Returns (b, n_tail) singular values."""
    s2 = jnp.maximum(sig_l.astype(jnp.float32) ** 2, 1e-30)     # (b,)
    x = tail_energy / s2                    # target Σρ^j in [0, n_tail]
    lo = jnp.zeros_like(x)
    hi = jnp.ones_like(x)
    for _ in range(30):                     # fp32 bisection on (0, 1)
        mid = 0.5 * (lo + hi)
        f = mid * (1.0 - mid ** n_tail) / (1.0 - mid + 1e-12)
        below = f < x
        lo = jnp.where(below, mid, lo)
        hi = jnp.where(below, hi, mid)
    rho = 0.5 * (lo + hi)
    j = jnp.arange(1, n_tail + 1, dtype=jnp.float32)
    t = s2[:, None] * rho[:, None] ** j                         # (b, n)
    t = t * (tail_energy / jnp.maximum(t.sum(axis=1), 1e-30))[:, None]
    return jnp.sqrt(jnp.minimum(t, s2[:, None]))


def _dec_rsvd(W, L, sL, k, oversample, iters, seed):
    """Randomized range-finder decomposition. Only GEMMs touch the large
    dimensions; the eigh is (k+oversample)². The returned spectrum is the
    top-l estimate EXTENDED by a synthetic geometric tail carrying the
    exact truncated energy (trace identity): ``‖M‖²_F = Σσ²`` is a cheap
    elementwise reduce, so ``tail = ‖M‖²_F − Σ_top-l σ̂²`` distributed
    over the min(d1, n·d2) − l unseen slots (``_tail_spectrum``) keeps
    total energy — and hence effective-rank allocation — honest for rsvd
    buckets instead of silently dropping the tail (DESIGN.md §1.5)."""
    b, d1, nd2 = W.shape
    ell = min(k + oversample, d1, nd2)
    M = _whiten_big(W, L, sL)
    omega = jax.random.normal(jax.random.PRNGKey(seed), (b, nd2, ell),
                              dtype=jnp.float32)
    Q = _qr_q(_bmm(M, omega))
    for _ in range(iters):
        Q = _qr_q(_bmm(M, _tn_project(M, Q)))
    T = _tn_project(M, Q)                           # Mᵀ Q : (b, nd2, l)
    lam, Uh = _eigh_desc(_tn_project(T, T))
    sig = jnp.sqrt(jnp.clip(lam, 0.0))              # top-l spectrum
    n_tail = min(d1, nd2) - ell
    if n_tail > 0:
        total = jnp.sum(M * M, axis=(1, 2))         # Σ σ², exact
        captured = jnp.sum(jnp.clip(lam, 0.0), axis=1)
        tail = jnp.maximum(total - captured, 0.0)
        sig = jnp.concatenate(
            [sig, _tail_spectrum(sig[:, ell - 1], tail, n_tail)], axis=1)
    Uk = _bmm(Q, Uh[:, :, :k])
    sigk = sig[:, :k]
    C = jnp.swapaxes(_bmm(T, Uh[:, :, :k]), 1, 2) \
        * (1.0 / jnp.maximum(sigk, 1e-20))[:, :, None]
    if L is not None:
        B = _solve_lower_t(L, Uk * sigk[:, None, :])
    elif sL is not None:
        B = (Uk * sigk[:, None, :]) / sL[:, :, None]
    else:
        B = Uk * sigk[:, None, :]
    return sig, B, C


def decompose(W: jax.Array, *, gram: Optional[jax.Array] = None,
              factor: Optional[jax.Array] = None,
              diag: Optional[jax.Array] = None,
              k: int, damp: float = 1e-6, rsvd: int = 0,
              rsvd_oversample: int = 8, rsvd_iters: int = 2,
              rsvd_seed: int = 0
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched whitened rank-k decomposition of ``W (b, d1, n·d2)``.

    Whitener: ``gram`` (b, d1, d1) → damped Cholesky on device; ``factor``
    (b, d1, d1) upper-triangular R with RᵀR = G (streaming whitening,
    skips the Cholesky); ``diag`` (b, d1) scale; none → identity.

    Returns ``(sig, B, C)`` with ``W ≈ B @ C`` at rank k in the ORIGINAL
    space, B (b, d1, k), C (b, k, n·d2), and sig the full whitened
    spectrum. With ``rsvd > 0`` only the top-(k+oversample) entries are
    estimated individually; the rest are a synthetic geometric-decay tail
    holding the exact truncated energy (``_tail_spectrum``), so
    allocation metrics built on Σσ² (effective rank, energy shares) stay
    calibrated.
    """
    assert sum(x is not None for x in (gram, factor, diag)) <= 1
    W = jnp.asarray(W).astype(jnp.float32)
    L = sL = None
    if gram is not None:
        L, _ = cholesky_escalate(jnp.asarray(gram), damp)
    elif factor is not None:
        L = jnp.swapaxes(_fix_factor(jnp.asarray(factor)), -1, -2)
    elif diag is not None:
        sL = jnp.asarray(diag).astype(jnp.float32)
    k = int(min(k, W.shape[-1], W.shape[-2]))
    if rsvd:
        return _dec_rsvd(W, L, sL, k, int(rsvd_oversample),
                         int(rsvd_iters), int(rsvd_seed))
    if W.shape[-2] <= W.shape[-1]:
        return _dec_left(W, L, sL, k)
    return _dec_right(W, L, sL, k)


# ---------------------------------------------------------------------------
# Batched refine solve: C* = (BᵀGB)⁻¹ BᵀGW
# ---------------------------------------------------------------------------
@jax.jit
def _refine_normal_eqs(L2, B, eps):
    """FᵀF and the damped BᵀGB from F = L₂ᵀB (SPD by construction)."""
    F = jnp.einsum("bji,bjk->bik", L2, B)
    BtGB = jnp.einsum("bdi,bdj->bij", F, F)
    k = B.shape[-1]
    tr = jnp.trace(BtGB, axis1=-2, axis2=-1) / max(1, k)
    BtGB = BtGB + (eps * jnp.maximum(tr, 1e-12))[:, None, None] \
        * jnp.eye(k, dtype=jnp.float32)
    return F, BtGB


def refine_solve(B: jax.Array, G: Optional[jax.Array], W: jax.Array,
                 eps: float = 1e-8,
                 factor: Optional[jax.Array] = None) -> jax.Array:
    """Batched closed-form coefficient update against a NEW Gram G
    (the refine pass re-captures G through the compressed model):

        C* = argmin_C ‖X(W − BC)‖_F = (BᵀGB + εI)⁻¹ BᵀGW.

    Factoring G = L₂L₂ᵀ once turns BᵀGB into FᵀF with F = L₂ᵀB and
    BᵀGW into (L₂ D)ᵀ W after the small solve D = (BᵀGB)⁻¹Fᵀ, so every
    large-dimension op is a GEMM and the solves are k×k / k×d only.
    B (b, d, k), G (b, d, d), W (b, d, m) → C (b, k, m).

    ``factor`` (upper-triangular R, RᵀR = G — the streaming-whitening
    form) replaces ``G``: L₂ = Rᵀ directly, so a whiten-streamed refine
    never materializes the Gram at all.
    """
    assert (G is None) != (factor is None)
    B = jnp.asarray(B).astype(jnp.float32)
    W = jnp.asarray(W).astype(jnp.float32)
    if factor is not None:
        L2 = jnp.swapaxes(_fix_factor(jnp.asarray(factor)), -1, -2)
    else:
        L2, _ = cholesky_escalate(jnp.asarray(G), 1e-9)
    F, BtGB = _refine_normal_eqs(L2, B, eps)
    Lk = jnp.linalg.cholesky(BtGB)
    D = _cho_solve(Lk, jnp.swapaxes(F, 1, 2))       # (b, k, d) — small RHS
    Et = _bmm(L2, jnp.swapaxes(D, 1, 2))            # L₂ Dᵀ : (b, d, k)
    return _tn_project(Et, W)                       # Etᵀ W = C*
