"""Calibration capture: per-linear input-activation statistics.

The compression pipeline needs, for every compressible weight matrix
``W (d_in, d_out)``, the Gram matrix of its calibration inputs
``G = Σ_batches XᵀX`` (fp64, host-side — the paper keeps the whitening
matrix S in fp64) plus the mean-|X| vector (ASVD's scaling).

Mechanism: model parameters are converted to *list form* (stacked layer runs
→ per-layer trees; see ``transformer._run_layers``), every linear's param
dict gets a ``"_tag"`` string key, and ``apply_linear`` reports ``(tag, x)``
to the active capture target (``repro.models.params.set_capture``). Two
targets exist:

  Collector        eager/host oracle — numpy fp64 accumulation, forward runs
                   op-by-op (never under jit; it raises on tracers).
  StreamingTape +  device-side streaming mode — the forward pass is traced
  StreamingCalibrator  inside a jit'd step function, every tagged activation
                   is reduced to a fp32 partial Gram ON DEVICE (Pallas
                   ``gram_blocked`` on TPU, XLA dot elsewhere), partials are
                   threaded functionally through donated accumulators, and
                   the host flushes them into fp64 sums every few batches
                   (DESIGN.md §6: fp32 partials + fp64 host-sum keep the
                   paper's fp64 S-matrix while calibration runs compiled
                   and multi-device; on a mesh, per-shard partials are
                   psum'd inside ``shard_map``).

MoE routed experts are captured separately: the dispatch buffers
``(E, capacity, d)`` that feed the per-expert GEMMs are reported by
``repro.models.mlp._moe_local`` under ``tag/expert{e}`` (padding rows are
exact zeros and contribute nothing to the Gram).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.dist.sharding import P, shard_map
from repro.models.params import Params, set_capture


class Collector:
    """Accumulates XᵀX (fp64) and Σ|x| per tag. Eager/host only — this is
    the precision oracle the streaming path is validated against."""

    def __init__(self):
        self.gram: Dict[str, np.ndarray] = {}
        self.absmean: Dict[str, np.ndarray] = {}
        self.count: Dict[str, int] = {}
        # streaming-whitening factors: upper-triangular R with RᵀR ≈ G,
        # for tags captured with StreamingCalibrator(whiten_tags=...) —
        # those tags have no entry in ``gram`` (that is the point: the
        # Gram never exists, on device or host)
        self.chol: Dict[str, np.ndarray] = {}

    def add(self, tag: str, x: jax.Array) -> None:
        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                "Collector is host-side/eager and cannot run under jit; "
                "use StreamingCalibrator / streaming_calibrate for the "
                "device-side capture path")
        x2 = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
        g = x2.T @ x2
        if tag in self.gram:
            self.gram[tag] += g
            self.absmean[tag] += np.abs(x2).sum(0)
            self.count[tag] += x2.shape[0]
        else:
            self.gram[tag] = g
            self.absmean[tag] = np.abs(x2).sum(0)
            self.count[tag] = x2.shape[0]

    def add_expert_batch(self, tag: str, xs: jax.Array) -> None:
        """xs: (E, capacity, d) dispatch buffers — one Gram per expert."""
        xs = np.asarray(xs, dtype=np.float64)
        for e in range(xs.shape[0]):
            self.add(f"{tag}/expert{e}", xs[e])

    def mean_abs(self, tag: str) -> np.ndarray:
        return self.absmean[tag] / max(1, self.count[tag])

    def __enter__(self):
        set_capture(self)
        return self

    def __exit__(self, *exc):
        set_capture(None)
        return False


# ---------------------------------------------------------------------------
# Streaming (jit/device) capture
# ---------------------------------------------------------------------------
class StreamingTape:
    """Trace-time capture target: collects per-tag fp32 partial statistics
    as jax values while a jit'd forward pass is being traced. The traced
    computation therefore CONTAINS the Gram reductions; the surrounding
    step function folds ``partials`` into the carried accumulators, so the
    side effect is confined to trace time and the result is functional."""

    def __init__(self, use_kernel: Optional[bool] = None,
                 whiten=None):
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel
        self.whiten = whiten            # True (all tags) or a set of tags
        self.partials: Dict[str, Dict[str, jax.Array]] = {}
        # raw fp32 activation blocks for whitened tags (these feed a QR
        # update instead of a Gram reduction; DESIGN.md §1.5)
        self.xblocks: Dict[str, list] = {}

    def _whitened(self, tag: str) -> bool:
        return _tag_whitened(self.whiten, tag)

    def _gram(self, x2: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as kops
            return kops.gram(x2)
        return jax.lax.dot_general(x2, x2, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def add(self, tag: str, x: jax.Array) -> None:
        x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
        part = {
            "absx": jnp.abs(x2).sum(0),
            "count": jnp.full((), x2.shape[0], dtype=jnp.int32),
        }
        if self._whitened(tag):
            self.xblocks.setdefault(tag, []).append(x2)
        else:
            part["gram"] = self._gram(x2)
        if tag in self.partials:
            self.partials[tag] = jax.tree.map(jnp.add, self.partials[tag],
                                              part)
        else:
            self.partials[tag] = part

    def add_expert_batch(self, tag: str, xs: jax.Array) -> None:
        for e in range(xs.shape[0]):
            self.add(f"{tag}/expert{e}", xs[e])

    def __enter__(self):
        set_capture(self)
        return self

    def __exit__(self, *exc):
        set_capture(None)
        return False


def _tag_whitened(whiten, tag: str) -> bool:
    """Shared predicate: ``whiten`` is True (all tags), a collection of
    tags, or None/falsy (off)."""
    return whiten is True or (whiten is not None and tag in whiten)


def _zero_accs(dims: Dict[str, int], whiten=None
               ) -> Dict[str, Dict[str, jax.Array]]:
    def entry(tag, d):
        stat = ({"chol": jnp.zeros((d, d), jnp.float32)}
                if _tag_whitened(whiten, tag)
                else {"gram": jnp.zeros((d, d), jnp.float32)})
        return {**stat, "absx": jnp.zeros((d,), jnp.float32),
                "count": jnp.zeros((), jnp.int32)}

    return {tag: entry(tag, d) for tag, d in dims.items()}


class _ShapeProbe:
    """Abstract capture target for tag/dim discovery under eval_shape."""

    def __init__(self):
        self.dims: Dict[str, int] = {}

    def add(self, tag: str, x) -> None:
        self.dims[tag] = int(x.shape[-1])

    def add_expert_batch(self, tag: str, xs) -> None:
        for e in range(xs.shape[0]):
            self.dims[f"{tag}/expert{e}"] = int(xs.shape[-1])


def discover_capture_dims(tagged: Params, cfg: ModelConfig,
                          batch: Dict) -> Dict[str, int]:
    """Enumerate every capture tag and its feature dim without running the
    model (abstract eval of one forward pass)."""
    from repro.models import transformer as T
    probe = _ShapeProbe()
    set_capture(probe)
    try:
        jax.eval_shape(lambda b: T.forward(tagged, cfg, b), batch)
    finally:
        set_capture(None)
    return probe.dims


class StreamingCalibrator:
    """Jit-compiled, device-side calibration capture (DESIGN.md §6).

    One jit'd step per batch shape: forward pass + on-device fp32 Gram
    partials per tag, folded into donated accumulators. Every
    ``flush_every`` batches the fp32 accumulators are pulled to host,
    added into fp64 sums and reset — bounding fp32 accumulation error
    while keeping the per-batch path free of host transfers.

    With ``mesh``, the per-batch partials are computed per data-parallel
    shard inside ``shard_map`` (batch rows split over ``data_axes``,
    params closed over and replicated) and combined with ``lax.psum``;
    the host then sees one replicated partial per batch, identical in
    layout to the single-device path.

    ``whiten_tags`` (True = every tag, or an explicit collection of tags)
    enables STREAMING WHITENING for those tags: instead of accumulating a
    Gram, the step function maintains the upper-triangular Cholesky factor
    of the running Gram directly — ``R' = qr_r([R; X_batch])`` — as a
    rank-revealing QR update on the raw fp32 activation rows. The Gram of
    a whitened tag is never materialized, on device or host; ``finalize``
    exposes the factor as ``Collector.chol[tag]`` and both the host
    whitener (``numerics.whitener_from_factor``) and the device
    decomposition (``numerics_jax.decompose(factor=...)``) consume it as
    is. QR-updating also sidesteps fp32 Gram-summation error (orthogonal
    transforms don't square the condition number), so no fp64 host flush
    is needed for these tags. Not supported together with ``mesh``.
    """

    def __init__(self, list_params: Params, cfg: ModelConfig, *,
                 mesh=None, data_axes=("pod", "data"),
                 flush_every: int = 8, use_kernel: Optional[bool] = None,
                 whiten_tags=None):
        self.cfg = cfg
        self.tagged = tag_linears(list_params)
        self.mesh = mesh
        self.flush_every = max(1, flush_every)
        self.use_kernel = use_kernel
        if whiten_tags is True:
            self.whiten = True
        elif whiten_tags:
            self.whiten = frozenset(whiten_tags)
        else:
            self.whiten = None
        if self.whiten is not None and mesh is not None:
            raise ValueError(
                "streaming whitening (whiten_tags) is host-mesh-exclusive "
                "for now: QR updates do not commute with per-shard psum; "
                "capture with mesh=None or whiten_tags=None")
        self._dims: Optional[Dict[str, int]] = None
        self._accs = None
        self._step = None
        self._since_flush = 0
        self._host: Dict[str, Dict[str, np.ndarray]] = {}
        if mesh is not None:
            axes = tuple(a for a in data_axes if a in mesh.axis_names)
            if not axes:
                raise ValueError(
                    f"mesh axes {mesh.axis_names} share nothing with "
                    f"data_axes {data_axes}")
            self.data_axes = axes
        else:
            self.data_axes = ()

    # -- step construction --------------------------------------------------
    def _tape_partials(self, batch):
        from repro.models import transformer as T
        tape = StreamingTape(self.use_kernel, whiten=self.whiten)
        with tape:
            T.forward(self.tagged, self.cfg, batch)
        return tape.partials, tape.xblocks

    def _build_step(self):
        if self.mesh is None:
            def step(accs, batch):
                parts, xblocks = self._tape_partials(batch)
                new = {}
                for tag, acc in accs.items():
                    p = parts[tag]
                    e = {"absx": acc["absx"] + p["absx"],
                         "count": acc["count"] + p["count"]}
                    if "chol" in acc:
                        stacked = jnp.concatenate(
                            [acc["chol"], *xblocks[tag]], axis=0)
                        e["chol"] = jnp.linalg.qr(stacked, mode="r")
                    else:
                        e["gram"] = acc["gram"] + p["gram"]
                    new[tag] = e
                return new
            return jax.jit(step, donate_argnums=0)

        axes = self.data_axes

        def shard_body(batch):
            parts, _ = self._tape_partials(batch)
            return jax.tree.map(lambda a: jax.lax.psum(a, axes), parts)

        sm = shard_map(shard_body, mesh=self.mesh,
                       in_specs=(P(axes),), out_specs=P())

        def step(accs, batch):
            return jax.tree.map(jnp.add, accs, sm(batch))
        return jax.jit(step, donate_argnums=0)

    # -- ingest / flush / finalize -----------------------------------------
    def ingest(self, batch: Dict) -> None:
        """Fold one calibration batch into the device accumulators."""
        if self._accs is None:
            self._dims = discover_capture_dims(self.tagged, self.cfg, batch)
            self._accs = _zero_accs(self._dims, self.whiten)
            self._step = self._build_step()
        self._accs = self._step(self._accs, batch)
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        """Pull fp32 device partials to host, fold into fp64, reset.
        Streaming-whitening factors stay resident on device (the QR chain
        is self-stabilizing; there is nothing to flush into fp64)."""
        if self._accs is None or self._since_flush == 0:
            return
        host = jax.device_get({
            tag: {k: v for k, v in acc.items() if k != "chol"}
            for tag, acc in self._accs.items()})
        for tag, acc in host.items():
            a = np.asarray(acc["absx"], dtype=np.float64)
            n = int(acc["count"])
            if tag in self._host:
                self._host[tag]["absx"] += a
                self._host[tag]["count"] += n
            else:
                self._host[tag] = {"absx": a, "count": n}
            if "gram" in acc:
                g = np.asarray(acc["gram"], dtype=np.float64)
                if "gram" in self._host[tag]:
                    self._host[tag]["gram"] += g
                else:
                    self._host[tag]["gram"] = g
        fresh = _zero_accs(self._dims, self.whiten)
        for tag, acc in self._accs.items():
            if "chol" in acc:
                fresh[tag]["chol"] = acc["chol"]
        self._accs = fresh
        self._since_flush = 0

    def sync(self) -> None:
        """Block until in-flight device work is done (benchmarking)."""
        if self._accs is not None:
            jax.block_until_ready(self._accs)

    def finalize(self) -> Collector:
        """Return the fp64 host-side statistics as a Collector (drop-in for
        the compression driver). Whitened tags expose their running
        Cholesky factor as ``col.chol[tag]`` and have no Gram entry."""
        self.flush()
        col = Collector()
        for tag, acc in self._host.items():
            if "gram" in acc:
                col.gram[tag] = acc["gram"]
            col.absmean[tag] = acc["absx"]
            col.count[tag] = acc["count"]
        if self._accs is not None:
            for tag, acc in self._accs.items():
                if "chol" in acc:
                    col.chol[tag] = np.asarray(
                        jax.device_get(acc["chol"]), dtype=np.float64)
        return col


def streaming_calibrate(list_params: Params, cfg: ModelConfig,
                        batches: Iterable[Dict], *, mesh=None,
                        flush_every: int = 8,
                        use_kernel: Optional[bool] = None,
                        whiten_tags=None) -> Collector:
    """Run the device-side streaming capture over ``batches`` and return the
    finalized fp64 Collector."""
    cal = StreamingCalibrator(list_params, cfg, mesh=mesh,
                              flush_every=flush_every, use_kernel=use_kernel,
                              whiten_tags=whiten_tags)
    for batch in batches:
        cal.ingest(batch)
    return cal.finalize()


# ---------------------------------------------------------------------------
# List-form params + tagging
# ---------------------------------------------------------------------------
def _is_linear(d) -> bool:
    return isinstance(d, dict) and ("w" in d or ("B" in d and "C" in d))


def to_list_params(params: Params, cfg: ModelConfig) -> Params:
    """Stacked layer runs -> lists of per-layer trees (deep copy of refs).
    Already-list runs pass through. Non-run subtrees are kept as-is."""
    out = dict(params)

    def split_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = rp
            else:
                new[f"run{r}"] = [
                    jax.tree.map(lambda a: a[i], rp) for i in range(n)]
        return new

    out["decoder"] = split_runs(params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = split_runs(params["encoder"], enc_cfg.layer_runs())
    return out


def to_stacked_params(list_params: Params, cfg: ModelConfig) -> Params:
    """Inverse of ``to_list_params`` (only valid if per-layer trees have
    identical leaf shapes — i.e. uncompressed or rank-padded)."""
    out = dict(list_params)

    def join_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a), *rp)
        return new

    out["decoder"] = join_runs(list_params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = join_runs(list_params["encoder"],
                                   enc_cfg.layer_runs())
    return out


def tag_linears(list_params: Params) -> Params:
    """Returns a shallow-copied tree where every linear dict carries its
    path as ``"_tag"`` (and MoE subtrees carry a dispatch tag)."""

    def walk(node, path):
        if _is_linear(node):
            d = dict(node)
            d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, dict):
            d = {}
            for k, v in node.items():
                d[k] = walk(v, path + (k,))
            if "w_gate" in node and "router" in node:   # routed-expert subtree
                d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, list):
            return [walk(v, path + (i,)) for i, v in enumerate(node)]
        return node

    return walk(list_params, ())


def strip_tags(params: Params) -> Params:
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items() if k != "_tag"}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)
