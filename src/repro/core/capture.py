"""Calibration capture: per-linear input-activation statistics.

The compression pipeline needs, for every compressible weight matrix
``W (d_in, d_out)``, the Gram matrix of its calibration inputs
``G = Σ_batches XᵀX`` (fp64, host-side — the paper keeps the whitening
matrix S in fp64) plus the mean-|X| vector (ASVD's scaling).

Mechanism: model parameters are converted to *list form* (stacked layer runs
→ per-layer trees; see ``transformer._run_layers``), every linear's param
dict gets a ``"_tag"`` string key, and ``apply_linear`` reports ``(tag, x)``
to the active Collector while the calibration batches run eagerly (capture
is a host-side side effect — never enable it under jit).

MoE routed experts are captured separately: the dispatch buffers
``(E, capacity, d)`` that feed the per-expert GEMMs are reported by
``repro.models.mlp._moe_local`` under ``tag/expert{e}`` (padding rows are
exact zeros and contribute nothing to the Gram).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.params import Params, set_capture


class Collector:
    """Accumulates XᵀX (fp64) and Σ|x| per tag."""

    def __init__(self):
        self.gram: Dict[str, np.ndarray] = {}
        self.absmean: Dict[str, np.ndarray] = {}
        self.count: Dict[str, int] = {}

    def add(self, tag: str, x: jax.Array) -> None:
        x2 = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
        g = x2.T @ x2
        if tag in self.gram:
            self.gram[tag] += g
            self.absmean[tag] += np.abs(x2).sum(0)
            self.count[tag] += x2.shape[0]
        else:
            self.gram[tag] = g
            self.absmean[tag] = np.abs(x2).sum(0)
            self.count[tag] = x2.shape[0]

    def add_expert_batch(self, tag: str, xs: jax.Array) -> None:
        """xs: (E, capacity, d) dispatch buffers — one Gram per expert."""
        xs = np.asarray(xs, dtype=np.float64)
        for e in range(xs.shape[0]):
            self.add(f"{tag}/expert{e}", xs[e])

    def mean_abs(self, tag: str) -> np.ndarray:
        return self.absmean[tag] / max(1, self.count[tag])

    def __enter__(self):
        set_capture(self)
        return self

    def __exit__(self, *exc):
        set_capture(None)
        return False


# ---------------------------------------------------------------------------
# List-form params + tagging
# ---------------------------------------------------------------------------
def _is_linear(d) -> bool:
    return isinstance(d, dict) and ("w" in d or ("B" in d and "C" in d))


def to_list_params(params: Params, cfg: ModelConfig) -> Params:
    """Stacked layer runs -> lists of per-layer trees (deep copy of refs).
    Already-list runs pass through. Non-run subtrees are kept as-is."""
    out = dict(params)

    def split_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = rp
            else:
                new[f"run{r}"] = [
                    jax.tree.map(lambda a: a[i], rp) for i in range(n)]
        return new

    out["decoder"] = split_runs(params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = split_runs(params["encoder"], enc_cfg.layer_runs())
    return out


def to_stacked_params(list_params: Params, cfg: ModelConfig) -> Params:
    """Inverse of ``to_list_params`` (only valid if per-layer trees have
    identical leaf shapes — i.e. uncompressed or rank-padded)."""
    out = dict(list_params)

    def join_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a), *rp)
        return new

    out["decoder"] = join_runs(list_params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = join_runs(list_params["encoder"],
                                   enc_cfg.layer_runs())
    return out


def tag_linears(list_params: Params) -> Params:
    """Returns a shallow-copied tree where every linear dict carries its
    path as ``"_tag"`` (and MoE subtrees carry a dispatch tag)."""

    def walk(node, path):
        if _is_linear(node):
            d = dict(node)
            d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, dict):
            d = {}
            for k, v in node.items():
                d[k] = walk(v, path + (k,))
            if "w_gate" in node and "router" in node:   # routed-expert subtree
                d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, list):
            return [walk(v, path + (i,)) for i, v in enumerate(node)]
        return node

    return walk(list_params, ())


def strip_tags(params: Params) -> Params:
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items() if k != "_tag"}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)
