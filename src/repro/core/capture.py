"""Calibration capture: per-linear input-activation statistics.

The compression pipeline needs, for every compressible weight matrix
``W (d_in, d_out)``, the Gram matrix of its calibration inputs
``G = Σ_batches XᵀX`` (fp64, host-side — the paper keeps the whitening
matrix S in fp64) plus the mean-|X| vector (ASVD's scaling).

Mechanism: model parameters are converted to *list form* (stacked layer runs
→ per-layer trees; see ``transformer._run_layers``), every linear's param
dict gets a ``"_tag"`` string key, and ``apply_linear`` reports ``(tag, x)``
to the active capture target (``repro.models.params.set_capture``). Two
targets exist:

  Collector        eager/host oracle — numpy fp64 accumulation, forward runs
                   op-by-op (never under jit; it raises on tracers).
  StreamingTape +  device-side streaming mode — the forward pass is traced
  StreamingCalibrator  inside a jit'd step function, every tagged activation
                   is reduced to a fp32 partial Gram ON DEVICE (Pallas
                   ``gram_blocked`` on TPU, XLA dot elsewhere), partials are
                   threaded functionally through donated accumulators, and
                   the host flushes them into fp64 sums every few batches
                   (DESIGN.md §7: fp32 partials + fp64 host-sum keep the
                   paper's fp64 S-matrix while calibration runs compiled
                   and multi-device; on a mesh, capture and reduction are
                   pipelined two-stage ``shard_map`` steps, with large
                   (D,D) accumulators optionally sharded row-wise and
                   whitening factors kept per shard until a tree-reduce
                   at finalize — DESIGN.md §1.6).

MoE routed experts are captured separately: the dispatch buffers
``(E, capacity, d)`` that feed the per-expert GEMMs are reported by
``repro.models.mlp._moe_local`` under ``tag/expert{e}`` (padding rows are
exact zeros and contribute nothing to the Gram).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.dist.sharding import (P, axis_group_size, combined_axis_index,
                                 logical_spec, shard_map)
from repro.models.params import Params, set_capture
from repro.obs import trace


class Collector:
    """Accumulates XᵀX (fp64) and Σ|x| per tag. Eager/host only — this is
    the precision oracle the streaming path is validated against."""

    def __init__(self):
        self.gram: Dict[str, np.ndarray] = {}
        self.absmean: Dict[str, np.ndarray] = {}
        self.count: Dict[str, int] = {}
        # streaming-whitening factors: upper-triangular R with RᵀR ≈ G,
        # for tags captured with StreamingCalibrator(whiten_tags=...) —
        # those tags have no entry in ``gram`` (that is the point: the
        # Gram never exists, on device or host)
        self.chol: Dict[str, np.ndarray] = {}

    def add(self, tag: str, x: jax.Array) -> None:
        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                "Collector is host-side/eager and cannot run under jit; "
                "use StreamingCalibrator / streaming_calibrate for the "
                "device-side capture path")
        x2 = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
        g = x2.T @ x2
        if tag in self.gram:
            self.gram[tag] += g
            self.absmean[tag] += np.abs(x2).sum(0)
            self.count[tag] += x2.shape[0]
        else:
            self.gram[tag] = g
            self.absmean[tag] = np.abs(x2).sum(0)
            self.count[tag] = x2.shape[0]

    def add_expert_batch(self, tag: str, xs: jax.Array) -> None:
        """xs: (E, capacity, d) dispatch buffers — one Gram per expert."""
        xs = np.asarray(xs, dtype=np.float64)
        for e in range(xs.shape[0]):
            self.add(f"{tag}/expert{e}", xs[e])

    def mean_abs(self, tag: str) -> np.ndarray:
        return self.absmean[tag] / max(1, self.count[tag])

    def __enter__(self):
        set_capture(self)
        return self

    def __exit__(self, *exc):
        set_capture(None)
        return False


# ---------------------------------------------------------------------------
# Streaming (jit/device) capture
# ---------------------------------------------------------------------------
class StreamingTape:
    """Trace-time capture target: collects per-tag fp32 partial statistics
    as jax values while a jit'd forward pass is being traced. The traced
    computation therefore CONTAINS the Gram reductions; the surrounding
    step function folds ``partials`` into the carried accumulators, so the
    side effect is confined to trace time and the result is functional.

    ``raw`` selects tags whose activation blocks are kept RAW (fp32 row
    blocks in ``xblocks``) instead of being reduced to a Gram at trace
    time: whitened tags feed a QR update, and — on a mesh — sharded-Gram
    tags feed the row-block fold, which needs the rows themselves
    (DESIGN.md §1.5/§1.6)."""

    def __init__(self, use_kernel: Optional[bool] = None,
                 whiten=None, raw=None):
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel
        self.whiten = whiten            # True (all tags) or a set of tags
        self.raw = raw                  # additional raw-block tags
        self.partials: Dict[str, Dict[str, jax.Array]] = {}
        self.xblocks: Dict[str, list] = {}

    def _keep_raw(self, tag: str) -> bool:
        return (_tag_whitened(self.whiten, tag)
                or _tag_whitened(self.raw, tag))

    def _gram(self, x2: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as kops
            return kops.gram(x2)
        return jax.lax.dot_general(x2, x2, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def add(self, tag: str, x: jax.Array) -> None:
        x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
        part = {
            "absx": jnp.abs(x2).sum(0),
            "count": jnp.full((), x2.shape[0], dtype=jnp.int32),
        }
        if self._keep_raw(tag):
            self.xblocks.setdefault(tag, []).append(x2)
        else:
            part["gram"] = self._gram(x2)
        if tag in self.partials:
            self.partials[tag] = jax.tree.map(jnp.add, self.partials[tag],
                                              part)
        else:
            self.partials[tag] = part

    def add_expert_batch(self, tag: str, xs: jax.Array) -> None:
        for e in range(xs.shape[0]):
            self.add(f"{tag}/expert{e}", xs[e])

    def __enter__(self):
        set_capture(self)
        return self

    def __exit__(self, *exc):
        set_capture(None)
        return False


def _tag_whitened(whiten, tag: str) -> bool:
    """Shared predicate: ``whiten`` is True (all tags), a collection of
    tags, or None/falsy (off)."""
    return whiten is True or (whiten is not None and tag in whiten)


def _spec_axes(spec) -> tuple:
    """First-dimension mesh axes of a PartitionSpec, as a flat tuple."""
    entry = spec[0] if len(spec) else None
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _zero_accs(dims: Dict[str, int], whiten=None
               ) -> Dict[str, Dict[str, jax.Array]]:
    def entry(tag, d):
        stat = ({"chol": jnp.zeros((d, d), jnp.float32)}
                if _tag_whitened(whiten, tag)
                else {"gram": jnp.zeros((d, d), jnp.float32)})
        return {**stat, "absx": jnp.zeros((d,), jnp.float32),
                "count": jnp.zeros((), jnp.int32)}

    return {tag: entry(tag, d) for tag, d in dims.items()}


class _ShapeProbe:
    """Abstract capture target for tag/dim discovery under eval_shape."""

    def __init__(self):
        self.dims: Dict[str, int] = {}

    def add(self, tag: str, x) -> None:
        self.dims[tag] = int(x.shape[-1])

    def add_expert_batch(self, tag: str, xs) -> None:
        for e in range(xs.shape[0]):
            self.dims[f"{tag}/expert{e}"] = int(xs.shape[-1])


def discover_capture_dims(tagged: Params, cfg: ModelConfig,
                          batch: Dict) -> Dict[str, int]:
    """Enumerate every capture tag and its feature dim without running the
    model (abstract eval of one forward pass)."""
    from repro.models import transformer as T
    probe = _ShapeProbe()
    set_capture(probe)
    try:
        jax.eval_shape(lambda b: T.forward(tagged, cfg, b), batch)
    finally:
        set_capture(None)
    return probe.dims


class StreamingCalibrator:
    """Jit-compiled, device-side calibration capture (DESIGN.md §1.3/§1.6).

    One jit'd step per batch shape: forward pass + on-device fp32 Gram
    partials per tag, folded into donated accumulators. Every
    ``flush_every`` batches the fp32 accumulators are pulled to host,
    added into fp64 sums and reset — bounding fp32 accumulation error
    while keeping the per-batch path free of host transfers.

    With ``mesh``, capture is a two-stage pipeline (DESIGN.md §1.6):
    stage 1 (``_capture``) runs the forward pass per data-parallel shard
    inside ``shard_map`` (batch rows split over ``data_axes``, params
    closed over and replicated) and emits per-shard partials with NO
    collectives; stage 2 (``_fold``) reduces the PREVIOUS batch's
    partials into the donated accumulators. ``ingest`` dispatches stage 1
    of batch k+1 before stage 2 of batch k, so the per-batch
    psum/all-gather latency hides behind the next forward pass
    (double-buffered: the in-flight partials are the second buffer).

    Accumulator layout on a mesh is routed per tag:

      replicated  (D, D) fp32 Gram on every device; per-shard partial
                  Grams are ``lax.psum``'d in the fold. The default for
                  small D.
      sharded     tags with ``D >= shard_grams_above`` (and divisible)
                  keep the (D, D) accumulator SHARDED row-wise over the
                  data axes — each device owns a (D/n_shards, D) block
                  and folds its rows of XᵀX from all-gathered activation
                  rows, so no device ever materializes a full (D, D)
                  buffer. Flush reassembles the blocks on host in fp64.
      whiten      see below: one QR factor per shard, tree-reduced at
                  finalize.

    ``whiten_tags`` (True = every tag, or an explicit collection of tags)
    enables STREAMING WHITENING for those tags: instead of accumulating a
    Gram, the step maintains the upper-triangular Cholesky factor of the
    running Gram directly — ``R' = qr_r([R; X_batch])`` — as a QR update
    on the raw fp32 activation rows. The Gram of a whitened tag is never
    materialized, on device or host; ``finalize`` exposes the factor as
    ``Collector.chol[tag]`` and both the host whitener
    (``numerics.whitener_from_factor``) and the device decomposition
    (``numerics_jax.decompose(factor=...)``) consume it as is.
    QR-updating also sidesteps fp32 Gram-summation error (orthogonal
    transforms don't square the condition number), so no fp64 host flush
    is needed for these tags. On a mesh, each shard QR-updates its OWN
    factor over its slice of the data (QR updates don't commute with
    psum, so nothing is reduced per batch); ``finalize`` merges the
    per-shard factors with ``numerics_jax.tree_reduce_factors`` —
    pairwise ``R' = qr_r([R_a; R_b])`` rounds whose result is exact
    (``RᵀR = Σ_s R_sᵀR_s`` = the global Gram) for any reduction order.

    Example (single device; pass ``mesh=`` for the sharded path)::

        >>> import jax
        >>> from repro.configs import get_config
        >>> from repro.core.capture import (StreamingCalibrator,
        ...                                 to_list_params)
        >>> from repro.models import transformer as T
        >>> cfg = get_config("llama-mini").replace(
        ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        ...     head_dim=16, d_ff=64, vocab_size=128)
        >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        >>> cal = StreamingCalibrator(to_list_params(params, cfg), cfg)
        >>> for i in range(2):
        ...     cal.ingest({"tokens": jax.random.randint(
        ...         jax.random.PRNGKey(i), (2, 16), 0, cfg.vocab_size)})
        >>> col = cal.finalize()
        >>> sorted(col.gram)[0], col.count[sorted(col.gram)[0]]
        ('decoder/run0/0/attn/wk', 64)
    """

    def __init__(self, list_params: Params, cfg: ModelConfig, *,
                 mesh=None, data_axes=("pod", "data"),
                 flush_every: int = 8, use_kernel: Optional[bool] = None,
                 whiten_tags=None, shard_grams_above: int = 4096):
        self.cfg = cfg
        self.tagged = tag_linears(list_params)
        self.mesh = mesh
        self.flush_every = max(1, flush_every)
        self.use_kernel = use_kernel
        self.shard_grams_above = shard_grams_above
        if whiten_tags is True:
            self.whiten = True
        elif whiten_tags:
            self.whiten = frozenset(whiten_tags)
        else:
            self.whiten = None
        self._dims: Optional[Dict[str, int]] = None
        self._routes: Dict[str, str] = {}
        self._accs = None
        self._step = None
        self._capture = None
        self._folds = ()
        self._make_zeros = None
        self._pending = None
        self._since_flush = 0
        self._host: Dict[str, Dict[str, np.ndarray]] = {}
        if mesh is not None:
            axes = tuple(a for a in data_axes if a in mesh.axis_names)
            if not axes:
                raise ValueError(
                    f"mesh axes {mesh.axis_names} share nothing with "
                    f"data_axes {data_axes}")
            self.data_axes = axes
            self.n_shards = axis_group_size(mesh, axes)
            # accumulator layouts resolve through the logical sharding
            # rules (dist.sharding): "gram_rows" for the row split of
            # sharded (D,D) accumulators, "calib_shard" for the
            # per-shard stack of whitening factors. The fold math rides
            # the batch split, so gram rows must shard a SUBSET of the
            # data axes and the factor stack must match them exactly.
            self.row_axes = tuple(
                a for a in _spec_axes(logical_spec(("gram_rows",), mesh))
                if a in axes)
            stack = _spec_axes(logical_spec(("calib_shard",), mesh))
            if tuple(a for a in stack if a in axes) != axes:
                raise ValueError(
                    f"calib_shard rule {stack} must cover the capture "
                    f"data axes {axes}: each data shard QR-updates its "
                    f"own factor over its slice of the batch")
        else:
            self.data_axes = ()
            self.n_shards = 1
            self.row_axes = ()

    # -- routing ------------------------------------------------------------
    def _route_of(self, tag: str, d: int) -> str:
        if _tag_whitened(self.whiten, tag):
            return "whiten"
        if (self.mesh is not None and self.shard_grams_above
                and self.row_axes
                and d >= self.shard_grams_above
                and d % axis_group_size(self.mesh, self.row_axes) == 0):
            return "sharded"
        return "replicated"

    @property
    def routes(self) -> Dict[str, str]:
        """tag -> accumulator route ('whiten' | 'sharded' | 'replicated');
        populated after the first ``ingest``."""
        return dict(self._routes)

    # -- step construction --------------------------------------------------
    def _tape_partials(self, batch, raw=None):
        from repro.models import transformer as T
        tape = StreamingTape(self.use_kernel, whiten=self.whiten, raw=raw)
        with tape:
            T.forward(self.tagged, self.cfg, batch)
        return tape.partials, tape.xblocks

    def _build_step(self):
        """Single-device path: one fused jit (forward + fold)."""
        def step(accs, batch):
            parts, xblocks = self._tape_partials(batch)
            new = {}
            for tag, acc in accs.items():
                p = parts[tag]
                e = {"absx": acc["absx"] + p["absx"],
                     "count": acc["count"] + p["count"]}
                if "chol" in acc:
                    stacked = jnp.concatenate(
                        [acc["chol"], *xblocks[tag]], axis=0)
                    e["chol"] = jnp.linalg.qr(stacked, mode="r")
                else:
                    e["gram"] = acc["gram"] + p["gram"]
                new[tag] = e
            return new
        return jax.jit(step, donate_argnums=0)

    def _build_mesh_steps(self):
        """Mesh path: capture stage (per-shard partials, no collectives)
        plus per-route fold stages (all collectives + accumulator update).
        Folds are split so the whiten fold's LAPACK QR never shares an
        executable with the Gram folds' big GEMMs (XLA:CPU runs dots ~3×
        slower next to LAPACK custom calls; see numerics_jax)."""
        axes = self.data_axes
        mesh = self.mesh
        raw_tags = frozenset(t for t, r in self._routes.items()
                             if r in ("whiten", "sharded"))

        def part_spec(tag):
            key = "x" if tag in raw_tags else "gram"
            return {"absx": P(axes), "count": P(axes), key: P(axes)}

        def capture_body(batch):
            parts, xblocks = self._tape_partials(batch, raw=raw_tags)
            out = {}
            for tag, p in parts.items():
                e = {"absx": p["absx"][None], "count": p["count"][None]}
                if tag in xblocks:
                    e["x"] = jnp.concatenate(xblocks[tag], axis=0)
                else:
                    e["gram"] = p["gram"][None]
                out[tag] = e
            return out

        capture = jax.jit(shard_map(
            capture_body, mesh=mesh, in_specs=(P(axes),),
            out_specs={t: part_spec(t) for t in self._dims}))

        def stat_fold(acc, p):
            return {"absx": acc["absx"] + jax.lax.psum(p["absx"][0], axes),
                    "count": acc["count"]
                    + jax.lax.psum(p["count"][0], axes)}

        def fold_gram_body(accs, parts):
            new = {}
            for tag, acc in accs.items():
                p = parts[tag]
                e = stat_fold(acc, p)
                if "x" in p:        # sharded accumulator: row block of XᵀX
                    Xa = p["x"]
                    for a in reversed(axes):
                        Xa = jax.lax.all_gather(Xa, a, axis=0, tiled=True)
                    blk = acc["gram"].shape[0]      # local row-block size
                    off = combined_axis_index(self.row_axes, mesh) * blk
                    Xf = jax.lax.dynamic_slice_in_dim(Xa, off, blk, axis=1)
                    e["gram"] = acc["gram"] + jax.lax.dot_general(
                        Xf, Xa, (((0,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
                else:
                    e["gram"] = acc["gram"] + jax.lax.psum(p["gram"][0],
                                                           axes)
                new[tag] = e
            return new

        def fold_whiten_body(accs, parts):
            new = {}
            for tag, acc in accs.items():
                p = parts[tag]
                e = stat_fold(acc, p)
                stacked = jnp.concatenate([acc["chol"][0], p["x"]], axis=0)
                e["chol"] = jnp.linalg.qr(stacked, mode="r")[None]
                new[tag] = e
            return new

        def acc_spec(tag):
            route = self._routes[tag]
            stat = {"absx": P(), "count": P()}
            if route == "whiten":
                return {**stat, "chol": P(axes)}
            if route == "sharded":
                return {**stat, "gram": P(self.row_axes, None)}
            return {**stat, "gram": P()}

        folds = []
        for body, pred in ((fold_gram_body, ("replicated", "sharded")),
                           (fold_whiten_body, ("whiten",))):
            tags = sorted(t for t, r in self._routes.items() if r in pred)
            if not tags:
                continue
            sm = shard_map(
                body, mesh=mesh,
                in_specs=({t: acc_spec(t) for t in tags},
                          {t: part_spec(t) for t in tags}),
                out_specs={t: acc_spec(t) for t in tags})
            folds.append((tuple(tags), jax.jit(sm, donate_argnums=0)))
        return capture, tuple(folds)

    # -- accumulator construction -------------------------------------------
    def _fresh_accs(self):
        """Zeroed flushable accumulators (gram/absx/count) with the routed
        shardings. On a mesh the zeros are produced ON DEVICE by a jitted
        init with explicit ``out_shardings`` — flush resets every
        ``flush_every`` batches, and shipping host zero buffers (256 MB
        per sharded tag at D=16k) over H2D each time would serialize
        behind the pipelined capture/fold work. Whiten-route factors are
        NOT included: they are never reset (``_init_chol`` seeds them
        once; flush carries them over)."""
        if self.mesh is None:
            return _zero_accs(self._dims, self.whiten)
        if self._make_zeros is None:
            NS = jax.sharding.NamedSharding
            shapes: Dict[str, Dict] = {}
            shards: Dict[str, Dict] = {}
            for tag, d in self._dims.items():
                route = self._routes[tag]
                sh = {"absx": ((d,), jnp.float32),
                      "count": ((), jnp.int32)}
                sp = {"absx": NS(self.mesh, P()),
                      "count": NS(self.mesh, P())}
                if route == "sharded":
                    sh["gram"] = ((d, d), jnp.float32)
                    sp["gram"] = NS(self.mesh, P(self.row_axes, None))
                elif route == "replicated":
                    sh["gram"] = ((d, d), jnp.float32)
                    sp["gram"] = NS(self.mesh, P())
                shapes[tag], shards[tag] = sh, sp
            self._make_zeros = jax.jit(
                lambda: {t: {k: jnp.zeros(*s) for k, s in e.items()}
                         for t, e in shapes.items()},
                out_shardings=shards)
        return self._make_zeros()

    def _init_chol(self, accs) -> None:
        """Seed the per-shard whitening-factor stacks (first ingest only;
        a one-off H2D of zeros per whiten tag)."""
        NS = jax.sharding.NamedSharding
        for tag, d in self._dims.items():
            if self._routes[tag] == "whiten":
                accs[tag]["chol"] = jax.device_put(
                    np.zeros((self.n_shards, d, d), np.float32),
                    NS(self.mesh, P(self.data_axes)))

    # -- ingest / flush / finalize -----------------------------------------
    def ingest(self, batch: Dict) -> None:
        """Fold one calibration batch into the device accumulators."""
        with trace.span("calib_ingest", since_flush=self._since_flush):
            if self._accs is None:
                self._dims = discover_capture_dims(self.tagged, self.cfg,
                                                   batch)
                self._routes = {t: self._route_of(t, d)
                                for t, d in self._dims.items()}
                self._accs = self._fresh_accs()
                if self.mesh is None:
                    self._step = self._build_step()
                else:
                    self._init_chol(self._accs)
                    self._capture, self._folds = self._build_mesh_steps()
            if self.mesh is None:
                self._accs = self._step(self._accs, batch)
            else:
                # dispatch the next capture BEFORE reducing the previous
                # batch's partials: both are queued asynchronously, so the
                # fold's collectives overlap the new forward pass
                parts = self._capture(batch)
                self._fold_pending()
                self._pending = parts
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def _fold_pending(self) -> None:
        if self._pending is None:
            return
        parts, self._pending = self._pending, None
        for tags, fold in self._folds:
            new = fold({t: self._accs[t] for t in tags},
                       {t: parts[t] for t in tags})
            self._accs.update(new)

    def flush(self) -> None:
        """Reduce pending partials, pull fp32 device accumulators to host,
        fold into fp64, reset. Sharded (D,D) accumulators reassemble on
        host (device_get gathers the row blocks); streaming-whitening
        factors stay resident on device (the QR chain is self-stabilizing;
        there is nothing to flush into fp64)."""
        if self._accs is None or self._since_flush == 0:
            return
        with trace.span("calib_flush", batches=self._since_flush):
            self._flush_inner()

    def _flush_inner(self) -> None:
        self._fold_pending()
        host = jax.device_get({
            tag: {k: v for k, v in acc.items() if k != "chol"}
            for tag, acc in self._accs.items()})
        for tag, acc in host.items():
            a = np.asarray(acc["absx"], dtype=np.float64)
            n = int(acc["count"])
            if tag in self._host:
                self._host[tag]["absx"] += a
                self._host[tag]["count"] += n
            else:
                self._host[tag] = {"absx": a, "count": n}
            if "gram" in acc:
                g = np.asarray(acc["gram"], dtype=np.float64)
                if "gram" in self._host[tag]:
                    self._host[tag]["gram"] += g
                else:
                    self._host[tag]["gram"] = g
        fresh = self._fresh_accs()
        for tag, acc in self._accs.items():
            if "chol" in acc:
                fresh[tag]["chol"] = acc["chol"]
        self._accs = fresh
        self._since_flush = 0

    def sync(self) -> None:
        """Drain the pending fold and block until in-flight device work is
        done (benchmarking / completion barrier)."""
        self._fold_pending()
        if self._accs is not None:
            jax.block_until_ready(self._accs)

    def finalize(self) -> Collector:
        """Return the fp64 host-side statistics as a Collector (drop-in for
        the compression driver). Whitened tags expose their running
        Cholesky factor as ``col.chol[tag]`` and have no Gram entry; on a
        mesh the per-shard factors are tree-reduced first (exact — see
        ``numerics_jax.tree_reduce_factors``)."""
        with trace.span("calib_finalize"):
            return self._finalize_inner()

    def _finalize_inner(self) -> Collector:
        self.flush()
        col = Collector()
        for tag, acc in self._host.items():
            if "gram" in acc:
                col.gram[tag] = acc["gram"]
            col.absmean[tag] = acc["absx"]
            col.count[tag] = acc["count"]
        if self._accs is not None:
            for tag, acc in self._accs.items():
                if "chol" in acc:
                    R = acc["chol"]
                    if self.mesh is not None:   # (n_shards, d, d) stack
                        from repro.core import numerics_jax as numj
                        R = numj.tree_reduce_factors(
                            jnp.asarray(jax.device_get(R)))
                    col.chol[tag] = np.asarray(
                        jax.device_get(R), dtype=np.float64)
        return col


def streaming_calibrate(list_params: Params, cfg: ModelConfig,
                        batches: Iterable[Dict], *, mesh=None,
                        flush_every: int = 8,
                        use_kernel: Optional[bool] = None,
                        whiten_tags=None,
                        shard_grams_above: int = 4096) -> Collector:
    """Run the device-side streaming capture over ``batches`` and return the
    finalized fp64 Collector (see ``StreamingCalibrator`` for the mesh,
    whitening and sharded-accumulator knobs)."""
    cal = StreamingCalibrator(list_params, cfg, mesh=mesh,
                              flush_every=flush_every, use_kernel=use_kernel,
                              whiten_tags=whiten_tags,
                              shard_grams_above=shard_grams_above)
    for batch in batches:
        cal.ingest(batch)
    return cal.finalize()


# ---------------------------------------------------------------------------
# List-form params + tagging
# ---------------------------------------------------------------------------
def _is_linear(d) -> bool:
    return isinstance(d, dict) and ("w" in d or ("B" in d and "C" in d))


def to_list_params(params: Params, cfg: ModelConfig) -> Params:
    """Stacked layer runs -> lists of per-layer trees (deep copy of refs).
    Already-list runs pass through. Non-run subtrees are kept as-is."""
    out = dict(params)

    def split_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = rp
            else:
                new[f"run{r}"] = [
                    jax.tree.map(lambda a: a[i], rp) for i in range(n)]
        return new

    out["decoder"] = split_runs(params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = split_runs(params["encoder"], enc_cfg.layer_runs())
    return out


def to_stacked_params(list_params: Params, cfg: ModelConfig) -> Params:
    """Inverse of ``to_list_params`` (only valid if per-layer trees have
    identical leaf shapes — i.e. uncompressed or rank-padded)."""
    out = dict(list_params)

    def join_runs(stack: Dict, runs) -> Dict:
        new = dict(stack)
        for r, (_kind, n) in enumerate(runs):
            rp = stack[f"run{r}"]
            if isinstance(rp, list):
                new[f"run{r}"] = jax.tree.map(lambda *a: jnp.stack(a), *rp)
        return new

    out["decoder"] = join_runs(list_params["decoder"], cfg.layer_runs())
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        out["encoder"] = join_runs(list_params["encoder"],
                                   enc_cfg.layer_runs())
    return out


def tag_linears(list_params: Params) -> Params:
    """Returns a shallow-copied tree where every linear dict carries its
    path as ``"_tag"`` (and MoE subtrees carry a dispatch tag)."""

    def walk(node, path):
        if _is_linear(node):
            d = dict(node)
            d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, dict):
            d = {}
            for k, v in node.items():
                d[k] = walk(v, path + (k,))
            if "w_gate" in node and "router" in node:   # routed-expert subtree
                d["_tag"] = "/".join(map(str, path))
            return d
        if isinstance(node, list):
            return [walk(v, path + (i,)) for i, v in enumerate(node)]
        return node

    return walk(list_params, ())


def strip_tags(params: Params) -> Params:
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items() if k != "_tag"}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)
