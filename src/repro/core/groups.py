"""Enumerate compressible weight matrices and build cross-layer groups.

Matrix inventory follows the model substrate's block structure
(``repro.models.transformer``); each entry records where the weight lives in
the list-form params tree, its matrix *type* (q/k/v/o/gate/up/down + family
analogues), its global layer index, and the capture tag that holds its
calibration Gram.

Grouping policy (paper §3.1/§3.4):
  * groupable types (q, k, v, up, gate + analogues) are concatenated across
    `group_size` consecutive layers and share one basis;
  * W_down / W_O are never grouped;
  * GQA models use group_size = 1 (paper's LLaMA-3 finding) — applied when
    ``gqa_group_one`` and cfg.n_kv_heads < cfg.n_heads;
  * routed MoE experts are each their own group (n = 1) but participate in
    the global rank allocation (beyond-paper extension, DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


from repro.config import ModelConfig
from repro.models.params import Params

# type -> groupable across layers
GROUPABLE = {
    "q": True, "k": True, "v": True, "o": False,
    "gate": True, "up": True, "down": False,
    "cq": True, "ck": True, "cv": True, "co": False,
    "eq": True, "ek": True, "ev": True, "eo": False,
    "egate": True, "eup": True, "edown": False,
    "sgate": True, "sup": True, "sdown": False,          # MoE shared experts
    "mup": True, "mgate": True, "mq": True, "mk": True, "mdown": False,
    "lin": True, "lfgate": True, "lfup": True, "lfdown": False,  # sLSTM
    "ssm_in": True, "ssm_z": True, "ssm_bc": False, "ssm_out": False,
    "xgate": False, "xup": False, "xdown": False,        # routed experts
}

# β-rebalance donor/receiver types (paper: W^Q, W^K -> W^V), per stack
BETA_MAP = [
    (("q", "k"), "v"),
    (("cq", "ck"), "cv"),
    (("eq", "ek"), "ev"),
]


@dataclass
class MatrixRef:
    path: Tuple                  # keys into list-form params, ending at the
    #                              linear dict (e.g. ("decoder","run0",0,"attn","wq"))
    mtype: str
    layer: int                   # global layer index (enc layers offset +1000)
    tag: str                     # Gram key in the Collector
    d_in: int = 0
    d_out: int = 0
    expert: Optional[int] = None  # routed-expert index (array-slice member)


@dataclass
class Group:
    gid: str
    mtype: str
    members: List[MatrixRef]

    @property
    def n(self) -> int:
        return len(self.members)

    @property
    def d_in(self) -> int:
        return self.members[0].d_in

    @property
    def d_out(self) -> int:
        return self.members[0].d_out

    @property
    def omega(self) -> int:
        return self.d_in + self.n * self.d_out

    @property
    def dense_params(self) -> int:
        return self.n * self.d_in * self.d_out

    @property
    def max_rank(self) -> int:
        return min(self.d_in, self.n * self.d_out)

    @property
    def cost_cap(self) -> int:
        """Largest k at which the factorized form is no bigger than dense."""
        return min(self.max_rank, self.dense_params // self.omega)


_BLOCK_TABLE = {
    # sub-module -> {param name -> type}
    "attn": {"wq": "q", "wk": "k", "wv": "v", "wo": "o"},
    "cross": {"wq": "cq", "wk": "ck", "wv": "cv", "wo": "co"},
    "mlp": {"w_gate": "gate", "w_up": "up", "w_down": "down"},
    "moe_shared": {"w_gate": "sgate", "w_up": "sup", "w_down": "sdown"},
    "mlstm": {"w_up": "mup", "w_gate": "mgate", "wq": "mq", "wk": "mk",
              "w_down": "mdown"},
    "slstm": {"w_in": "lin", "ff_gate": "lfgate", "ff_up": "lfup",
              "ff_down": "lfdown"},
    "ssm": {"w_in": "ssm_in", "w_z": "ssm_z", "w_bc": "ssm_bc",
            "w_out": "ssm_out"},
}

_ENC_TABLE = {
    "attn": {"wq": "eq", "wk": "ek", "wv": "ev", "wo": "eo"},
    "mlp": {"w_gate": "egate", "w_up": "eup", "w_down": "edown"},
}


def _linear_dims(d: Dict) -> Tuple[int, int]:
    w = d["w"]
    return int(w.shape[-2]), int(w.shape[-1])


def enumerate_matrices(list_params: Params, cfg: ModelConfig,
                       include_experts: bool = True) -> List[MatrixRef]:
    refs: List[MatrixRef] = []

    def walk_stack(stack: Dict, runs, table, base_path, layer0: int):
        layer = layer0
        for r, (_kind, n) in enumerate(runs):
            layers = stack[f"run{r}"]
            assert isinstance(layers, list), "enumerate needs list-form params"
            for i, lp in enumerate(layers):
                for sub, names in table.items():
                    if sub not in lp:
                        continue
                    for pname, mtype in names.items():
                        if pname not in lp[sub]:
                            continue
                        d = lp[sub][pname]
                        if "w" not in d:
                            continue       # already factorized
                        din, dout = _linear_dims(d)
                        path = base_path + (f"run{r}", i, sub, pname)
                        refs.append(MatrixRef(
                            path=path, mtype=mtype, layer=layer,
                            tag="/".join(map(str, path)),
                            d_in=din, d_out=dout))
                # routed experts: stacked arrays under lp["moe"]
                if include_experts and "moe" in lp and "w_gate" in lp["moe"]:
                    moe_tag = "/".join(map(str, base_path
                                           + (f"run{r}", i, "moe")))
                    E = int(lp["moe"]["w_gate"].shape[0])
                    dd = int(lp["moe"]["w_gate"].shape[1])
                    ff = int(lp["moe"]["w_gate"].shape[2])
                    for e in range(E):
                        base = base_path + (f"run{r}", i, "moe")
                        refs.append(MatrixRef(
                            path=base + ("w_gate",), mtype="xgate",
                            layer=layer, expert=e,
                            tag=f"{moe_tag}/in/expert{e}",
                            d_in=dd, d_out=ff))
                        refs.append(MatrixRef(
                            path=base + ("w_up",), mtype="xup",
                            layer=layer, expert=e,
                            tag=f"{moe_tag}/in/expert{e}",
                            d_in=dd, d_out=ff))
                        refs.append(MatrixRef(
                            path=base + ("w_down",), mtype="xdown",
                            layer=layer, expert=e,
                            tag=f"{moe_tag}/mid/expert{e}",
                            d_in=ff, d_out=dd))
                layer += 1

    walk_stack(list_params["decoder"], cfg.layer_runs(), _BLOCK_TABLE,
               ("decoder",), 0)
    if cfg.is_encoder_decoder and "encoder" in list_params:
        enc_cfg = cfg.replace(n_layers=cfg.n_encoder_layers,
                              sliding_window=0, local_global_pattern=(0, 0))
        walk_stack(list_params["encoder"], enc_cfg.layer_runs(), _ENC_TABLE,
                   ("encoder",), 1000)
    return refs


def build_groups(refs: Sequence[MatrixRef], cfg: ModelConfig,
                 group_size: int, gqa_group_one: bool = True) -> List[Group]:
    n = group_size
    if gqa_group_one and cfg.n_kv_heads < cfg.n_heads:
        n = 1          # paper §3.4: GQA models use per-layer compression
    by_type: Dict[str, List[MatrixRef]] = {}
    for ref in refs:
        by_type.setdefault(ref.mtype, []).append(ref)
    groups: List[Group] = []
    for mtype, items in by_type.items():
        items = sorted(items, key=lambda r: (r.layer, r.expert or 0))
        if mtype.startswith("x"):        # routed experts: one group each
            for ref in items:
                groups.append(Group(
                    gid=f"{mtype}:L{ref.layer}e{ref.expert}",
                    mtype=mtype, members=[ref]))
            continue
        size = n if GROUPABLE.get(mtype, False) else 1
        for j in range(0, len(items), size):
            chunk = items[j:j + size]
            groups.append(Group(
                gid=f"{mtype}:g{j // size}", mtype=mtype, members=chunk))
    return groups
