"""D-Rank core: the paper's contribution as a composable module.
capture (calibration Grams) -> numerics (whitened SVD, effective rank) ->
groups (cross-layer grouping policies) -> allocate (Lagrange closed form,
beta rebalance, integerization; beyond-paper energy water-filling) ->
compress (driver + the five baselines)."""
from repro.core.capture import (StreamingCalibrator,  # noqa: F401
                                streaming_calibrate)
from repro.core.compress import (CompressionConfig, METHODS, Plan,  # noqa
                                 build_plan_and_params, calibrate,
                                 load_plan, save_plan)
from repro.core.numerics import effective_rank  # noqa: F401
