"""D-Rank compression driver + the five baselines.

Methods (all post-training, calibration-data-driven):
  svd      plain truncated SVD             (no whitening, n=1, uniform k)
  fwsvd    Fisher-weighted SVD             (diag row weights from E[g²])
  asvd     activation-aware SVD            (diag scale (mean|X|)^α)
  svdllm   whitened SVD                    (Cholesky of XᵀX, n=1, uniform)
  basis    Basis Sharing                   (whitened, grouped n>1, uniform)
  drank    THE PAPER: whitened, grouped (GQA→n=1), effective-rank Lagrange
           allocation + β attention rebalance.

The driver runs eagerly on host (calibration capture is a side effect); the
deploy artifact is a list-form params tree whose linears are factorized
{B, C} with a shared basis per group, loadable straight into the model
(``transformer._run_layers`` executes list runs unrolled).
"""
from __future__ import annotations

import copy
import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import allocate as alloc
from repro.core import numerics as num
from repro.core import numerics_jax as numj
from repro.core.capture import (Collector, streaming_calibrate, strip_tags,
                                tag_linears, to_list_params)
from repro.core.groups import (BETA_MAP, Group, MatrixRef, build_groups,
                               enumerate_matrices)
from repro.models import transformer as T
from repro.models.params import Params
from repro.obs import trace

METHODS = ("svd", "fwsvd", "asvd", "svdllm", "basis", "drank", "dranke")


@dataclass(frozen=True)
class CompressionConfig:
    method: str = "drank"
    ratio: float = 0.2              # fraction of compressible params removed
    group_size: int = 2             # cross-layer group width (n)
    beta: float = 0.35              # Q/K -> V rank transfer (paper: 0.3-0.4)
    rank_multiple: int = 1          # MXU alignment (128 on TPU deploys)
    min_rank: int = 1
    asvd_alpha: float = 0.5
    damp: float = 1e-6
    gqa_group_one: bool = True      # paper §3.4 GQA policy
    include_experts: bool = True    # compress routed MoE experts too
    refine: bool = False            # closed-form C update on compressed acts
    type_filter: Tuple[str, ...] = ()   # restrict to these types (tests)
    # device path (numerics_jax): min-side size above which the exact
    # batched eigh switches to the randomized range-finder; 0 = never
    rsvd_threshold: int = 0
    rsvd_oversample: int = 8
    rsvd_iters: int = 2


# ---------------------------------------------------------------------------
# Calibration passes
# ---------------------------------------------------------------------------
def calibrate(list_params: Params, cfg: ModelConfig,
              batches: Iterable[Dict], *, streaming: bool = True,
              mesh=None, whiten_tags=None,
              shard_grams_above: int = 4096) -> Collector:
    """Collect per-tag Gram statistics over the calibration batches.

    ``streaming=True`` (default) runs the jit-compiled device-side capture
    (fp32 partials on device, fp64 host finalization; shard-aware when a
    ``mesh`` is given — see ``capture.StreamingCalibrator``). The eager
    host path (``streaming=False``) is the fp64 oracle it is validated
    against (tests/test_calib_capture.py) and needs no compile step.
    ``whiten_tags`` (streaming only) captures those tags as streaming
    Cholesky factors instead of Grams — on a mesh, per shard, tree-reduced
    at finalize. ``shard_grams_above`` routes tags whose feature dim
    reaches it to row-sharded (D,D) accumulators when a mesh is given.

    Example::

        >>> import jax
        >>> from repro.configs import get_config
        >>> from repro.core import compress as CC
        >>> from repro.core.capture import to_list_params
        >>> from repro.models import transformer as T
        >>> cfg = get_config("llama-mini").replace(
        ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        ...     head_dim=16, d_ff=64, vocab_size=128)
        >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        >>> lp = to_list_params(params, cfg)
        >>> batch = {"tokens": jax.random.randint(
        ...     jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}
        >>> col = CC.calibrate(lp, cfg, [batch])
        >>> col.gram["decoder/run0/0/attn/wq"].shape
        (32, 32)
    """
    if streaming:
        return streaming_calibrate(list_params, cfg, batches, mesh=mesh,
                                   whiten_tags=whiten_tags,
                                   shard_grams_above=shard_grams_above)
    if whiten_tags:
        raise ValueError(
            "whiten_tags requires streaming=True: the eager fp64 oracle "
            "materializes every Gram by construction, so a non-streaming "
            "whitened capture would silently void the memory guarantee")
    tagged = tag_linears(list_params)
    col = Collector()
    with col:
        for batch in batches:
            T.forward(tagged, cfg, batch)
    return col


def fisher_rows(list_params: Params, cfg: ModelConfig,
                batches: Iterable[Dict]) -> Dict[str, np.ndarray]:
    """FWSVD row weights: w_i = sqrt(Σ_j E[g_ij²]) per weight matrix tag."""
    clean = strip_tags(list_params)
    grad_fn = jax.grad(lambda p, b: T.lm_loss(p, cfg, b)[0])
    acc = None
    nb = 0
    for batch in batches:
        g = grad_fn(clean, batch)
        g2 = jax.tree.map(lambda a: np.asarray(a, dtype=np.float64) ** 2, g)
        acc = g2 if acc is None else jax.tree.map(np.add, acc, g2)
        nb += 1
    fisher: Dict[str, np.ndarray] = {}
    if acc is None:
        return fisher

    def get(tree, path):
        node = tree
        for k in path:
            node = node[k]
        return node

    for ref in enumerate_matrices(list_params, cfg, include_experts=False):
        f = get(acc, ref.path)["w"] / max(1, nb)
        fisher[ref.tag] = np.sqrt(f.sum(axis=-1) + 1e-12)   # (d_in,)
    return fisher


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------
@dataclass
class GroupResult:
    gid: str
    mtype: str
    layers: List[int]
    expert: Optional[int]
    d_in: int
    d_out: int
    n: int
    omega: int
    reff: float
    k: int
    kmax: int
    sigma_head: List[float] = field(default_factory=list)


@dataclass
class Plan:
    config: CompressionConfig
    groups: List[GroupResult]
    summary: Dict[str, float]

    def to_json(self) -> str:
        return json.dumps({
            "config": dataclasses.asdict(self.config),
            "groups": [dataclasses.asdict(g) for g in self.groups],
            "summary": self.summary,
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "Plan":
        d = json.loads(s)
        cfgd = d["config"]
        cfgd["type_filter"] = tuple(cfgd.get("type_filter", ()))
        return Plan(
            config=CompressionConfig(**cfgd),
            groups=[GroupResult(**g) for g in d["groups"]],
            summary=d["summary"])

    def rank_of(self, gid: str) -> int:
        for g in self.groups:
            if g.gid == gid:
                return g.k
        raise KeyError(gid)


# ---------------------------------------------------------------------------
# Weight access
# ---------------------------------------------------------------------------
def _get_node(tree, path):
    node = tree
    for k in path:
        node = node[k]
    return node


def _member_weight(lp: Params, ref: MatrixRef,
                   dtype=np.float64) -> np.ndarray:
    node = _get_node(lp, ref.path)
    if ref.expert is not None:                   # stacked expert array
        return np.asarray(node[ref.expert]).astype(dtype)
    return np.asarray(node["w"]).astype(dtype)


# ---------------------------------------------------------------------------
# Device decomposition (numerics_jax): bucket same-shaped groups, one
# batched jit call per bucket
# ---------------------------------------------------------------------------
def _shard_group_batch(x: jax.Array, mesh):
    """Optionally spread a stacked group batch over the mesh's data axes
    (logical axis ``group_batch``; replicates when the batch does not
    divide — see dist.sharding.shape_aware_spec)."""
    if mesh is None:
        return x
    from repro.dist.sharding import shape_aware_spec
    axes = ("group_batch",) + (None,) * (x.ndim - 1)
    spec = shape_aware_spec(x.shape, axes, mesh)
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))


def _decompose_groups_device(
        lp: Params, groups: List[Group], ccfg: CompressionConfig,
        col: Optional[Collector], fisher: Optional[Dict[str, np.ndarray]],
        mesh=None) -> Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Whitened decomposition of every group at its cost cap, batched by
    shape bucket. Returns gid -> (sig fp64, B (d1,kmax), C (kmax,n·d2))
    with B/C in the ORIGINAL space; final ranks slice columns later."""
    buckets: Dict[Tuple, List[Group]] = {}
    for g in groups:
        buckets.setdefault((g.d_in, g.n * g.d_out, g.n, g.cost_cap),
                           []).append(g)
    out: Dict[str, Tuple] = {}
    for (d1, nd2, n, kmax), gs in sorted(buckets.items()):
        with trace.span("decompose_bucket", d1=d1, nd2=nd2,
                        kmax=kmax, n_groups=len(gs)):
            W = np.stack([
                np.concatenate([_member_weight(lp, m, dtype=np.float32)
                                for m in g.members], axis=1) for g in gs])
            kwargs: Dict = {}
            if ccfg.method == "fwsvd":
                # same floor as num.diag_whitener: zero Fisher rows (dead
                # units) must not divide the basis by zero
                kwargs["diag"] = np.maximum(np.stack(
                    [fisher[g.members[0].tag] for g in gs]), 1e-8
                ).astype(np.float32)
            elif ccfg.method == "asvd":
                kwargs["diag"] = np.stack([np.power(np.maximum(np.mean(
                    [col.mean_abs(m.tag) for m in g.members], axis=0),
                    1e-8), ccfg.asvd_alpha) for g in gs]).astype(np.float32)
            elif ccfg.method != "svd":               # cholesky family
                tags = [m.tag for g in gs for m in g.members]
                if col.chol and all(t in col.chol for t in tags):
                    Rs = np.stack(
                        [np.stack([col.chol[m.tag].astype(np.float32)
                                   for m in g.members]) for g in gs])
                    kwargs["factor"] = numj.combine_factors(
                        _shard_group_batch(jnp.asarray(Rs), mesh))
                else:
                    # buckets mixing whitened and plain tags fall back to
                    # Grams, substituting RᵀR for factor-only tags
                    kwargs["gram"] = _shard_group_batch(jnp.asarray(np.stack(
                        [np.sum([_gram_of(col, m.tag) for m in g.members],
                                axis=0) for g in gs]).astype(np.float32)),
                        mesh)
                    kwargs["damp"] = ccfg.damp
            rsvd = int(bool(ccfg.rsvd_threshold)
                       and min(d1, nd2) >= ccfg.rsvd_threshold)
            sig, B, C = numj.decompose(
                _shard_group_batch(jnp.asarray(W), mesh), k=kmax, rsvd=rsvd,
                rsvd_oversample=ccfg.rsvd_oversample,
                rsvd_iters=ccfg.rsvd_iters, **kwargs)
            sig = np.asarray(sig, dtype=np.float64)
            B = np.asarray(B)
            C = np.asarray(C)
            if not np.isfinite(sig).all():
                # device cholesky_escalate signals failure as NaNs; fail as
                # loudly as the host oracle does on non-finite Grams
                bad = [gs[i].gid for i in range(len(gs))
                       if not np.isfinite(sig[i]).all()]
                raise np.linalg.LinAlgError(
                    f"device decomposition produced non-finite spectra for "
                    f"groups {bad} (bucket d1={d1}, n·d2={nd2}) — "
                    f"non-finite calibration Grams or weights")
            for i, g in enumerate(gs):
                out[g.gid] = (sig[i], B[i], C[i])
    return out


# ---------------------------------------------------------------------------
# The driver
# ---------------------------------------------------------------------------
def _whitener_for(group: Group, ccfg: CompressionConfig, col: Collector,
                  fisher: Optional[Dict[str, np.ndarray]]) -> num.Whitener:
    if ccfg.method == "svd":
        return num.identity_whitener()
    if ccfg.method == "fwsvd":
        return num.diag_whitener(fisher[group.members[0].tag])
    if ccfg.method == "asvd":
        s = np.mean([col.mean_abs(m.tag) for m in group.members], axis=0)
        return num.diag_whitener(np.power(np.maximum(s, 1e-8),
                                          ccfg.asvd_alpha))
    # cholesky family. Streaming-whitened tags carry an upper-triangular
    # factor RᵀR = G instead of a Gram (capture.StreamingCalibrator
    # whiten_tags): members merge by stacked QR, never forming G.
    tags = [m.tag for m in group.members]
    if col.chol and all(t in col.chol for t in tags):
        R = np.vstack([col.chol[t] for t in tags])
        return num.whitener_from_factor(np.linalg.qr(R, mode="r"))
    # otherwise aggregate the group's Grams (DESIGN.md §1.2); a group can
    # mix whitened and plain members (whiten_tags is per-tag) — the
    # factor's RᵀR stands in for the missing Gram
    G = None
    for m in group.members:
        g = _gram_of(col, m.tag)
        G = g if G is None else G + g
    return num.cholesky_whitener(G, ccfg.damp)


def _gram_of(col: Collector, tag: str) -> np.ndarray:
    if tag in col.gram:
        return col.gram[tag]
    R = col.chol[tag]
    return R.T @ R


def build_plan_and_params(
        params: Params, cfg: ModelConfig, ccfg: CompressionConfig,
        calib_batches: Sequence[Dict],
        collector: Optional[Collector] = None,
        streaming: bool = True,
        device: bool = False,
        mesh=None,
        whiten_tags=None,
        shard_grams_above: int = 4096,
) -> Tuple[Params, Plan]:
    """Compress. Returns (list-form compressed params, plan).

    ``streaming`` selects the capture path when no ``collector`` is
    supplied (see ``calibrate``). ``device=True`` dispatches the
    decomposition math (whitening, whitened SVD, truncation, refine) to
    the jit-compiled fp32 backend in ``numerics_jax`` — same-shaped
    groups decompose in one batched call; rank allocation is unchanged
    and works on the device-computed spectra. The host fp64 path
    (``device=False``) is the precision oracle it is validated against
    (tests/test_compress_device.py). With a ``mesh``, calibration shards
    over the data axes and stacked group batches are placed along the
    logical ``group_batch`` axis. ``whiten_tags`` (True = all; streaming
    capture only) streams whitening factors instead of Grams for those
    tags, mesh or not — see ``capture.StreamingCalibrator``.

    Example (compress a tiny model 30% and check the plan)::

        >>> import jax
        >>> from repro.configs import get_config
        >>> from repro.core import compress as CC
        >>> from repro.models import transformer as T
        >>> cfg = get_config("llama-mini").replace(
        ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        ...     head_dim=16, d_ff=64, vocab_size=128, rank_multiple=1)
        >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        >>> calib = [{"tokens": jax.random.randint(
        ...     jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}]
        >>> ccfg = CC.CompressionConfig(method="drank", ratio=0.3,
        ...                             group_size=2)
        >>> comp, plan = CC.build_plan_and_params(params, cfg, ccfg, calib)
        >>> abs(plan.summary["achieved_ratio"] - 0.3) < 0.05
        True
        >>> sorted({g.mtype for g in plan.groups})
        ['down', 'gate', 'k', 'o', 'q', 'up', 'v']
    """
    assert ccfg.method in METHODS, ccfg.method
    lp = to_list_params(params, cfg)

    needs_col = ccfg.method != "svd" or ccfg.refine
    col = collector
    if col is None and needs_col:
        with trace.span("calibrate", batches=len(calib_batches),
                        streaming=streaming):
            col = calibrate(lp, cfg, calib_batches, streaming=streaming,
                            mesh=mesh, whiten_tags=whiten_tags,
                            shard_grams_above=shard_grams_above)
    fisher = (fisher_rows(lp, cfg, calib_batches)
              if ccfg.method == "fwsvd" else None)

    include_x = ccfg.include_experts and ccfg.method in (
        "basis", "drank", "dranke", "svdllm")
    refs = enumerate_matrices(lp, cfg, include_experts=include_x)
    if ccfg.type_filter:
        refs = [r for r in refs if r.mtype in ccfg.type_filter]

    group_size = ccfg.group_size if ccfg.method in ("basis", "drank",
                                                    "dranke") else 1
    gqa_one = ccfg.gqa_group_one and ccfg.method in ("drank", "dranke")
    groups = build_groups(refs, cfg, group_size, gqa_group_one=gqa_one)

    # ---- decompose every group, collect spectra ---------------------------
    # host: per-group fp64 whitening + SVD (the oracle); device: batched
    # fp32 jit calls, one per shape bucket, factors kept at the cost cap
    svds: Dict[str, Tuple] = {}
    dec: Dict[str, Tuple] = {}
    sig_of: Dict[str, np.ndarray] = {}
    if device:
        dec = _decompose_groups_device(lp, groups, ccfg, col, fisher, mesh)
        sig_of = {gid: d[0] for gid, d in dec.items()}
    else:
        with trace.span("decompose_host", n_groups=len(groups)):
            for g in groups:
                W_cat = np.concatenate(
                    [_member_weight(lp, m) for m in g.members], axis=1)
                wh = _whitener_for(g, ccfg, col, fisher) if col or fisher \
                    else num.identity_whitener()
                U, sig, Vt = num.whitened_svd(W_cat, wh)
                svds[g.gid] = (U, sig, Vt, wh)
                sig_of[g.gid] = sig
    gspecs: List[alloc.GroupSpec] = []
    for g in groups:
        gspecs.append(alloc.GroupSpec(
            gid=g.gid, mtype=g.mtype, reff=num.effective_rank(sig_of[g.gid]),
            omega=g.omega, kmax=g.cost_cap, kmin=ccfg.min_rank,
            dense_params=g.dense_params))

    # ---- allocate ---------------------------------------------------------
    budget = (1.0 - ccfg.ratio) * sum(s.dense_params for s in gspecs)
    if ccfg.method == "drank":
        kf = alloc.lagrange_allocate(gspecs, budget)
        for qk, v in BETA_MAP:
            kf = alloc.beta_rebalance(gspecs, kf, ccfg.beta,
                                      qk_types=qk, v_type=v)
        ks = alloc.integerize(gspecs, kf, budget,
                              multiple=ccfg.rank_multiple)
    elif ccfg.method == "dranke":
        ks = alloc.energy_allocate(gspecs, sig_of, budget,
                                   multiple=ccfg.rank_multiple)
    else:
        ks = alloc.uniform_allocate(gspecs, ccfg.ratio,
                                    multiple=ccfg.rank_multiple)

    # ---- build factorized params -----------------------------------------
    new_lp = copy.deepcopy(jax.tree.map(lambda x: x, lp))
    pdt = jnp.dtype(cfg.param_dtype)
    results: List[GroupResult] = []
    expert_factors: Dict[Tuple, Dict[int, Tuple]] = {}

    for g, gs in zip(groups, gspecs):
        k = ks[g.gid]
        if device:
            sig, Bfull, Cfull = dec[g.gid]
            B, C = Bfull[:, :k], Cfull[:k]
        else:
            U, sig, Vt, wh = svds[g.gid]
            B, C = num.truncate_factors(U, sig, Vt, k, wh)
        Bj = jnp.asarray(B, dtype=pdt)
        for i, m in enumerate(g.members):
            Ci = jnp.asarray(C[:, i * g.d_out:(i + 1) * g.d_out], dtype=pdt)
            if m.expert is not None:
                expert_factors.setdefault(m.path, {})[m.expert] = (Bj, Ci)
            else:
                node = _get_node(new_lp, m.path)
                new_node = {"B": Bj, "C": Ci}
                if "b" in node:
                    new_node["b"] = node["b"]
                parent = _get_node(new_lp, m.path[:-1])
                parent[m.path[-1]] = new_node
        results.append(GroupResult(
            gid=g.gid, mtype=g.mtype,
            layers=[m.layer for m in g.members],
            expert=g.members[0].expert,
            d_in=g.d_in, d_out=g.d_out, n=g.n, omega=g.omega,
            reff=gs.reff, k=k, kmax=gs.kmax,
            sigma_head=[float(s) for s in sig[:8]]))

    # routed experts: restack with zero rank padding (exact)
    for path, factors in expert_factors.items():
        arr = _get_node(lp, path)
        E = arr.shape[0]
        rmax = max(f[0].shape[1] for f in factors.values())
        d_in = arr.shape[1]
        d_out = arr.shape[2]
        Bs = np.zeros((E, d_in, rmax), dtype=np.float32)
        Cs = np.zeros((E, rmax, d_out), dtype=np.float32)
        for e in range(E):
            if e in factors:
                Be, Ce = factors[e]
                r = Be.shape[1]
                Bs[e, :, :r] = np.asarray(Be, dtype=np.float32)
                Cs[e, :r, :] = np.asarray(Ce, dtype=np.float32)
            else:   # padding experts (router-masked): keep zeros
                pass
        parent = _get_node(new_lp, path[:-1])
        parent[path[-1]] = {"B": jnp.asarray(Bs, dtype=pdt),
                            "C": jnp.asarray(Cs, dtype=pdt)}

    summary = alloc.allocation_summary(gspecs, ks)
    plan = Plan(config=ccfg, groups=results, summary=summary)
    if ccfg.refine:
        # if calibration streamed whitening factors, the refine
        # re-capture must too — otherwise it would re-materialize the
        # very Grams whiten_tags exists to avoid (the mesh path streams
        # per-shard factors and tree-reduces them, so it qualifies)
        wt = (frozenset(col.chol) if col is not None and col.chol
              and streaming else None)
        with trace.span("refine", n_groups=len(groups)):
            new_lp = refine_coefficients(
                lp, new_lp, cfg, groups, calib_batches,
                streaming=streaming, device=device, mesh=mesh,
                whiten_tags=wt, shard_grams_above=shard_grams_above)
    return new_lp, plan


def refine_coefficients(orig_lp: Params, comp_lp: Params, cfg: ModelConfig,
                        groups: List[Group],
                        calib_batches: Sequence[Dict],
                        streaming: bool = True, device: bool = False,
                        mesh=None, whiten_tags=None,
                        shard_grams_above: int = 4096) -> Params:
    """Closed-form downstream update (the paper's ≥40% trick, after
    SVD-LLM): re-collect Grams THROUGH the compressed model (inputs now
    deviate from the originals) and re-solve each coefficient matrix

        C_i* = argmin_C ‖X_new (W_i − B C)‖_F = (Bᵀ G B)⁻¹ Bᵀ G W_i .

    ``device=True`` batches the solves: members are bucketed by
    (d_in, k, d_out) and each bucket runs one jitted fp32
    ``numerics_jax.refine_solve`` (Cholesky + triangular solves) instead
    of a host fp64 loop.

    ``whiten_tags`` re-captures those tags as streaming Cholesky factors
    (see ``capture.StreamingCalibrator``); the device solve then runs in
    factor form (L₂ = Rᵀ), so a fully whiten-streamed refine never
    materializes a Gram — the memory guarantee of whiten_tags holds
    through the refine pass.
    """
    col2 = calibrate(comp_lp, cfg, calib_batches, streaming=streaming,
                     mesh=mesh, whiten_tags=whiten_tags,
                     shard_grams_above=shard_grams_above)
    members = [m for g in groups for m in g.members
               if m.expert is None
               and (m.tag in col2.gram or m.tag in col2.chol)]
    if device:
        buckets: Dict[Tuple, List[MatrixRef]] = {}
        for m in members:
            node = _get_node(comp_lp, m.path)
            buckets.setdefault(
                (m.d_in, int(node["B"].shape[1]), m.d_out), []).append(m)
        for key, ms in sorted(buckets.items()):
            B = jnp.stack([jnp.asarray(_get_node(comp_lp, m.path)["B"],
                                       dtype=jnp.float32) for m in ms])
            W = jnp.asarray(np.stack(
                [_member_weight(orig_lp, m, dtype=np.float32)
                 for m in ms]))
            if all(m.tag in col2.chol for m in ms):
                R = jnp.asarray(np.stack(
                    [col2.chol[m.tag] for m in ms]).astype(np.float32))
                C = numj.refine_solve(_shard_group_batch(B, mesh), None,
                                      _shard_group_batch(W, mesh),
                                      factor=_shard_group_batch(R, mesh))
            else:
                G = jnp.asarray(np.stack(
                    [_gram_of(col2, m.tag) for m in ms]
                ).astype(np.float32))
                C = numj.refine_solve(_shard_group_batch(B, mesh),
                                      _shard_group_batch(G, mesh),
                                      _shard_group_batch(W, mesh))
            C = np.asarray(C)
            for i, m in enumerate(ms):
                node = _get_node(comp_lp, m.path)
                node["C"] = jnp.asarray(C[i], dtype=node["C"].dtype)
        return comp_lp
    for m in members:
        node = _get_node(comp_lp, m.path)
        B = np.asarray(node["B"], dtype=np.float64)
        G = _gram_of(col2, m.tag)
        W = _member_weight(orig_lp, m)
        BtGB = B.T @ G @ B
        BtGB += 1e-8 * np.trace(BtGB) / max(1, len(BtGB)) * np.eye(
            B.shape[1])
        C = np.linalg.solve(BtGB, B.T @ G @ W)
        node["C"] = jnp.asarray(C, dtype=node["C"].dtype)
    return comp_lp


# ---------------------------------------------------------------------------
# Compressed-checkpoint round trip (deploy artifact)
# ---------------------------------------------------------------------------
ARTIFACT_NAME = "compressed"


def _model_fingerprint(cfg: ModelConfig) -> Dict:
    return {"name": cfg.name, "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "vocab_size": cfg.vocab_size,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads}


def save_plan(ckpt_dir: str, list_params: Params, plan: Plan,
              cfg: Optional[ModelConfig] = None) -> str:
    """Persist the factorized list-form params + allocation plan so serving
    can boot WITHOUT re-running compression. Shared group bases are stored
    once (``store.save_pytree`` aliases identical leaves), and the
    manifest records per-array content hashes for ``load_plan
    (verify=True)`` / ``serve.py --verify``.

    Example (full round trip; continues the ``build_plan_and_params``
    example)::

        >>> import tempfile, jax
        >>> from repro.configs import get_config
        >>> from repro.core import compress as CC
        >>> from repro.models import transformer as T
        >>> cfg = get_config("llama-mini").replace(
        ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        ...     head_dim=16, d_ff=64, vocab_size=128, rank_multiple=1)
        >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        >>> calib = [{"tokens": jax.random.randint(
        ...     jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}]
        >>> comp, plan = CC.build_plan_and_params(
        ...     params, cfg, CC.CompressionConfig(ratio=0.3), calib)
        >>> d = tempfile.mkdtemp()
        >>> path = CC.save_plan(d, comp, plan, cfg)
        >>> lp, plan2 = CC.load_plan(d, cfg=cfg, verify=True)
        >>> plan2.to_json() == plan.to_json()
        True
    """
    from repro.ckpt import store
    meta: Dict = {"plan": json.loads(plan.to_json())}
    if cfg is not None:
        meta["model"] = _model_fingerprint(cfg)
    return store.save_pytree(ckpt_dir, strip_tags(list_params), meta,
                             name=ARTIFACT_NAME)


def load_plan(ckpt_dir: str, cfg: Optional[ModelConfig] = None,
              verify: bool = False, retries: int = 0,
              quarantine: bool = False) -> Tuple[Params, Plan]:
    """Load a compressed artifact saved by ``save_plan``. If ``cfg`` is
    given, its fingerprint must match the one recorded at save time.
    ``verify=True`` re-hashes every stored array against the manifest
    content hashes before booting (see ``store.load_pytree``).
    ``retries > 0`` re-reads with exponential backoff on transient/
    integrity failures and, with ``quarantine=True``, moves a
    persistently failing artifact to ``<name>.quarantined`` before
    raising ``store.IntegrityError`` (serve.py ``--load-retries``)."""
    from repro.ckpt import store
    if retries > 0 or quarantine:
        params, meta = store.load_pytree_resilient(
            ckpt_dir, name=ARTIFACT_NAME, verify=verify, retries=retries,
            quarantine=quarantine)
    else:
        params, meta = store.load_pytree(ckpt_dir, name=ARTIFACT_NAME,
                                         verify=verify)
    plan = Plan.from_json(json.dumps(meta["plan"]))
    if cfg is not None and "model" in meta:
        want = _model_fingerprint(cfg)
        if want != meta["model"]:
            raise ValueError(
                f"compressed checkpoint was built for {meta['model']}, "
                f"got config {want}")
    return params, plan


# ---------------------------------------------------------------------------
# Serve-time elastic rank: pow2 bucket ladder over the saved factors
# ---------------------------------------------------------------------------
def _pow2_ceil(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def rank_bucket(r: int, level: int, min_rank: int = 1) -> int:
    """Rank served at degradation ``level`` for a factor of full rank
    ``r``: level 0 is the exact allocated rank; level ℓ ≥ 1 serves
    ``pow2_ceil(r) >> ℓ`` (clamped to [min_rank, r]) — roughly a halving
    per level, always a power of two, so the whole ladder compiles at
    most ``levels`` extra decode executables regardless of how many
    distinct allocated ranks the plan produced."""
    if level <= 0:
        return r
    return max(min_rank, min(r, _pow2_ceil(r) >> level))


def slice_rank_ladder(list_params: Params, levels: int = 2,
                      min_rank: int = 1) -> List[Params]:
    """Slice a factorized params tree into a serve-time degradation
    ladder (ISSUE 6 / ROADMAP "elastic serve-time rank").

    The factors are singular-value-ordered (B's columns / C's rows come
    out of the whitened SVD sorted by descending σ), so ``B[..., :k']``
    / ``C[..., :k', :]`` IS the optimal rank-k' truncation of the same
    decomposition — one saved artifact serves any rank ≤ k with a plain
    slice, no re-SVD, no retrace of anything but the (bounded) new factor
    shapes. Returns ``[full, level1, ..., levelN]`` where level ℓ slices
    every factorized linear to ``rank_bucket(r, ℓ)``:

    * level 0 is ``list_params`` ITSELF (same array objects), so the
      full-rank rung is token-identical to the pre-ladder engine by
      construction;
    * shared bases stay shared: a basis B reused across a group's layers
      is sliced once per (array, rank) and re-aliased, preserving the
      checkpoint dedup in every rung;
    * dense (``w``) linears, biases, LoRA adapters, norms are passed
      through by reference — the ladder only views factor prefixes, it
      copies nothing but slice views.

    Note: a ``refine=True`` coefficient matrix is optimal at its full
    rank, not per prefix; sliced rungs of a refined artifact are still
    valid low-rank approximations (B is unchanged), just not the refined
    optimum at the lower rank.
    """
    ladder = [list_params]
    for lvl in range(1, levels + 1):
        sliced_b: Dict[Tuple[int, int], jax.Array] = {}

        def walk(node, lvl=lvl, sliced_b=sliced_b):
            if isinstance(node, dict):
                if "B" in node and "C" in node:
                    B, C = node["B"], node["C"]
                    r = int(B.shape[-1])
                    k = rank_bucket(r, lvl, min_rank)
                    out = dict(node)
                    if k < r:
                        key = (id(B), k)
                        if key not in sliced_b:
                            sliced_b[key] = B[..., :k]
                        out["B"] = sliced_b[key]
                        out["C"] = C[..., :k, :]
                    return out
                return {kk: walk(v) for kk, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            if isinstance(node, tuple):
                return tuple(walk(v) for v in node)
            return node

        rung = walk(list_params)
        # a level that sliced nothing (dense tree, or every rank already
        # at its bucket) is the full tree — alias it so callers can
        # detect a degenerate ladder by identity
        ladder.append(rung if sliced_b else list_params)
    return ladder


def compressed_param_count(list_params: Params) -> int:
    """Parameter count with shared bases deduped by array identity."""
    seen = set()
    total = 0
    for leaf in jax.tree.leaves(list_params):
        if not hasattr(leaf, "size"):
            continue
        if id(leaf) in seen:
            continue
        seen.add(id(leaf))
        total += leaf.size
    return total
