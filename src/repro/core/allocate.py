"""Rank allocation: the paper's Lagrange-multiplier closed form (eq 13–19),
the β attention rebalance (eq 9–12), and the budget-exact integerization /
MXU-alignment layer (beyond-paper; DESIGN.md §7.1).

Optimization problem:   min Σ_g R_eff(g)/k_g   s.t.  Σ_g k_g ω_g = T_budget
Closed form:            k_g ∝ sqrt(R_eff(g) / ω_g)

with ω_g = d1 + n·d2 (params per unit rank of a shared-basis group). Groups
are clamped to [k_min, k_max] (k_max = rank cap AND cost-neutrality cap
n·d1·d2/ω) by iterative water-filling: clamped groups drop out and the
multiplier is re-solved on the rest.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np


@dataclass
class GroupSpec:
    gid: str
    mtype: str
    reff: float
    omega: int            # params per unit rank: d1 + n*d2
    kmax: int             # min(matrix rank cap, cost-neutral cap)
    kmin: int = 1
    dense_params: int = 0  # n * d1 * d2


def lagrange_allocate(groups: Sequence[GroupSpec],
                      budget: float) -> Dict[str, float]:
    """Water-filled closed-form allocation (float ranks)."""
    k: Dict[str, float] = {}
    clamped: Dict[str, float] = {}
    active = list(groups)
    for _ in range(len(groups) + 1):
        rem = budget - sum(clamped[g.gid] * g.omega for g in groups
                           if g.gid in clamped)
        denom = sum(math.sqrt(max(g.reff, 1e-9) * g.omega) for g in active)
        if not active or denom <= 0:
            break
        C = rem / denom
        changed = False
        for g in list(active):
            kg = C * math.sqrt(max(g.reff, 1e-9) / g.omega)
            if kg >= g.kmax:
                clamped[g.gid] = float(g.kmax)
                active.remove(g)
                changed = True
            elif kg <= g.kmin:
                clamped[g.gid] = float(g.kmin)
                active.remove(g)
                changed = True
            else:
                k[g.gid] = kg
        if not changed:
            break
    k.update(clamped)
    return k


def beta_rebalance(groups: Sequence[GroupSpec], k: Dict[str, float],
                   beta: float,
                   qk_types=("q", "k"), v_type: str = "v"
                   ) -> Dict[str, float]:
    """Paper eq (9)–(12): move a β-fraction of the Q/K rank budget to V,
    distributing the extracted rank evenly across V groups. Performed in
    *rank* units exactly as the paper defines (cost differences between Q/K
    and V groups are second-order; the integerization layer repairs the
    budget afterwards)."""
    if not beta:
        return dict(k)
    out = dict(k)
    by_type: Dict[str, List[GroupSpec]] = {}
    for g in groups:
        by_type.setdefault(g.mtype, []).append(g)
    vs = by_type.get(v_type, [])
    if not vs:
        return out
    extracted = 0.0
    for t in qk_types:
        for g in by_type.get(t, []):
            take = beta * out[g.gid]
            out[g.gid] -= take
            extracted += take
    t_add = extracted / len(vs)
    for g in vs:
        out[g.gid] = min(float(g.kmax), out[g.gid] + t_add)
    return out


def integerize(groups: Sequence[GroupSpec], k: Dict[str, float],
               budget: float, multiple: int = 1) -> Dict[str, int]:
    """Round ranks to `multiple` and repair the budget while staying as
    close as possible to the TARGET allocation `k` (which already encodes
    the Lagrange optimum *and* the β rebalance — the repair must preserve
    those proportions, not re-optimize them away).

    Greedy: shrink the group whose integer rank exceeds its target by the
    largest relative margin; grow the one furthest below target.
    """
    gm = {g.gid: g for g in groups}

    def clampk(g: GroupSpec, v: float) -> int:
        m = multiple
        vi = int(round(v / m)) * m
        lo = min(g.kmin, g.kmax)
        lo = max(lo, m if g.kmax >= m else 1)
        return int(max(lo, min(g.kmax, vi if vi > 0 else lo)))

    out = {gid: clampk(gm[gid], v) for gid, v in k.items()}

    def cost() -> int:
        return sum(out[g] * gm[g].omega for g in out)

    def over_target(g: GroupSpec) -> float:
        """Relative excess of the integer rank over its float target."""
        kg = out[g.gid]
        step = min(multiple, kg - max(1, min(g.kmin, kg)))
        if step <= 0:
            return -math.inf
        return (kg - k[g.gid]) / max(k[g.gid], 1.0)

    def under_target(g: GroupSpec) -> float:
        kg = out[g.gid]
        if kg + 1 > g.kmax:
            return -math.inf
        return (k[g.gid] - kg) / max(k[g.gid], 1.0)

    guard = 0
    while cost() > budget and guard < 100000:
        guard += 1
        g = max(groups, key=over_target)
        if over_target(g) is -math.inf:
            break
        kg = out[g.gid]
        out[g.gid] = kg - min(multiple, kg - max(1, min(gm[g.gid].kmin, kg)))
    guard = 0
    while guard < 100000:
        guard += 1
        cands = [g for g in groups if under_target(g) > 0]
        if not cands:
            break
        g = max(cands, key=under_target)
        step = multiple if out[g.gid] + multiple <= g.kmax \
            else g.kmax - out[g.gid]
        if step <= 0 or cost() + step * g.omega > budget:
            break
        out[g.gid] += step
    # top-up: if targets were capped (e.g. β pushed V to kmax) budget may be
    # left unspent — spend it on the relatively most-compressed groups so
    # the achieved ratio matches the requested one
    guard = 0
    while guard < 100000:
        guard += 1
        cands = [g for g in groups
                 if out[g.gid] < g.kmax
                 and cost() + min(multiple, g.kmax - out[g.gid]) * g.omega
                 <= budget]
        if not cands:
            break
        g = min(cands, key=lambda g: out[g.gid] / max(k[g.gid], 1.0))
        out[g.gid] += min(multiple, g.kmax - out[g.gid])
    return out


def energy_allocate(groups: Sequence[GroupSpec],
                    sigmas: Dict[str, "np.ndarray"], budget: float,
                    multiple: int = 1) -> Dict[str, int]:
    """BEYOND-PAPER allocator: greedy water-filling on the measured
    whitened spectra — buy the rank block with the highest marginal
    RELATIVE energy recovered per parameter:

        argmax_g  Σ_{i=k_g}^{k_g+m} σ̂_{g,i}²  / (m·ω_g),
        σ̂_g = σ_g / ‖σ_g‖          (scale-invariant, like R_eff)

    Globally optimal for the separable normalized-energy objective (σ² is
    non-increasing). Normalization matters: raw energy starves small-scale
    groups whose downstream sensitivity is large (measured: unnormalized
    greedy catastrophically breaks the model at 50%; see EXPERIMENTS.md
    §Claims). Beats the paper's R_eff/k proxy at 20–30% compression.
    """
    k = {g.gid: 0 for g in groups}
    spent = 0.0
    norm2 = {}
    for g in groups:
        s2 = np.asarray(sigmas[g.gid], dtype=np.float64) ** 2
        norm2[g.gid] = s2 / max(s2.sum(), 1e-30)

    def marginal(g: GroupSpec):
        kg = k[g.gid]
        m = min(multiple, g.kmax - kg)
        if m <= 0:
            return None
        gain = float(norm2[g.gid][kg:kg + m].sum())
        return (-gain / (m * g.omega), m, g.gid)

    heap = []
    gm = {g.gid: g for g in groups}
    for g in groups:
        entry = marginal(g)
        if entry:
            heapq.heappush(heap, entry)
    while heap:
        neg, m, gid = heapq.heappop(heap)
        g = gm[gid]
        cur = marginal(g)
        if cur is None or abs(cur[0] - neg) > 1e-18 * max(1, abs(neg)):
            if cur:
                heapq.heappush(heap, cur)      # stale entry, reinsert fresh
            continue
        if spent + m * g.omega > budget:
            continue
        k[gid] += m
        spent += m * g.omega
        nxt = marginal(g)
        if nxt:
            heapq.heappush(heap, nxt)
    for g in groups:                            # floors
        k[g.gid] = max(k[g.gid], min(g.kmin, g.kmax), 1)
    return k


def uniform_allocate(groups: Sequence[GroupSpec], ratio: float,
                     multiple: int = 1) -> Dict[str, int]:
    """The baselines' allocator: every group keeps the same parameter
    fraction — k_g = (1-θ)·dense_params/ω, independently of content."""
    out: Dict[str, int] = {}
    for g in groups:
        kf = (1.0 - ratio) * g.dense_params / g.omega
        m = multiple
        kg = int(round(kf / m)) * m if m > 1 else int(math.floor(kf))
        out[g.gid] = max(min(g.kmin, g.kmax), min(g.kmax, max(1, kg)))
    return out


def allocation_summary(groups: Sequence[GroupSpec],
                       k: Dict[str, int]) -> Dict[str, float]:
    dense = sum(g.dense_params for g in groups)
    comp = sum(k[g.gid] * g.omega for g in groups)
    return {
        "dense_params": dense,
        "compressed_params": comp,
        "achieved_ratio": 1.0 - comp / max(1, dense),
        "total_loss_proxy": sum(g.reff / max(1, k[g.gid]) for g in groups),
    }
