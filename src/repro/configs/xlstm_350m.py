"""xLSTM-350M [arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (no separate FFN; blocks carry their own
up/down projection) vocab=50304. sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    mlp_kind="none",
    vocab_size=50304,
    rope_kind="none",
    mlstm_every_slstm=8,   # layers 7, 15, 23 are sLSTM
    ssm_expand=2,
    dtype="bfloat16",
    param_dtype="float32",
)
