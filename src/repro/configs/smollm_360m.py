"""SmolLM-360M (llama-arch small) [hf:HuggingFaceTB/SmolLM-135M; hf].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10_000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    param_dtype="float32",
    # 15 heads / 2560 ff are small vs model=16 axis: shard FFN+vocab only
    shard_attn_heads=False,
)
