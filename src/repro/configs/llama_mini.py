"""LLaMA-family mini config — the paper's evaluation family at
experiment scale (the D-Rank paper compresses LLaMA-7B/13B/30B, LLaMA-2/3,
Mistral-7B). Used by EXPERIMENTS.md §Claims for the faithful small-scale
reproduction: train on the synthetic corpus, compress with all six methods,
compare PPL. MHA (kv == heads) so cross-layer grouping (n>1) is exercised
exactly as in the paper; a GQA variant is derived in the experiments to
exercise the paper's n=1 GQA policy.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-mini",
    family="dense",
    n_layers=8,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,           # MHA like LLaMA-1/2 7B
    head_dim=32,
    d_ff=688,
    vocab_size=2048,
    rope_theta=10_000.0,
    dtype="float32",
    param_dtype="float32",
    rank_multiple=8,
    sequence_parallel=False,
)
