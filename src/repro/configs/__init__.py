"""Architecture registry: one module per assigned architecture.

``get_config("<arch-id>")`` accepts the dashed public id (e.g.
``qwen2-vl-72b``) or the underscored module name.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "qwen2-vl-72b",
    "mistral-nemo-12b",
    "smollm-360m",
    "gemma3-12b",
    "qwen3-4b",
    "xlstm-350m",
    "hymba-1.5b",
    "seamless-m4t-medium",
    "granite-moe-1b-a400m",
    "qwen2-moe-a2.7b",
    # paper's own evaluation family (small-scale stand-in used in experiments)
    "llama-mini",
]

_cache: Dict[str, ModelConfig] = {}


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    key = arch_id.replace("_", "-")
    # tolerate either separator
    for cand in (arch_id, key):
        if cand in _cache:
            return _cache[cand]
    mod = importlib.import_module(f"repro.configs.{_module_name(key)}")
    cfg = mod.CONFIG
    _cache[key] = cfg
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
