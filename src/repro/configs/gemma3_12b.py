"""Gemma-3-12B [hf:google/gemma-3-1b-pt family; unverified].

48L d_model=3840 16H (GQA kv=8) head_dim=256 d_ff=15360 vocab=262144.
5 local (sliding-window 1024) : 1 global attention pattern, 128k ctx,
qk-norm, dual rope thetas (local 10k / global 1M).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    qk_norm=True,
    sliding_window=1024,
    local_global_pattern=(5, 1),
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embed=True,
    dtype="bfloat16",
    param_dtype="float32",
)
