"""SeamlessM4T-medium transformer backbone [arXiv:2308.11596; hf].

Enc-dec: 12L encoder + 12L decoder, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206. The audio frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings to the encoder.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    n_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_kind="none",          # learned/sinusoidal positions; we use sinusoidal
    mlp_kind="gelu",
    frontend="audio",
    dtype="bfloat16",
    param_dtype="float32",
)
