"""Qwen2-VL-72B language backbone [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064, M-RoPE.
The vision frontend is a STUB: ``input_specs`` feeds precomputed patch
embeddings + 3-component M-RoPE position ids.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    rope_kind="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim//2
    frontend="vision",
    dtype="bfloat16",
    param_dtype="float32",
)
