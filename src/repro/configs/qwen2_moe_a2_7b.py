"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (MHA kv=16) vocab=151936. MoE: 60 routed experts top-4
(padded to 64 for EP divisibility; router masks the padding) + shared
experts worth 4x d_expert=1408.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared=4, d_shared=1408,
                  capacity_factor=1.25, pad_to=64),
    dtype="bfloat16",
    param_dtype="float32",
)
