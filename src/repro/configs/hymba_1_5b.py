"""Hymba-1.5B [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Parallel attention + mamba heads per block; sliding-window attention except
global attention at layers {first, middle, last}. Meta-tokens are omitted
(frontend-stub policy, see DESIGN.md).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=10_000.0,
    sliding_window=1024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    dtype="bfloat16",
    param_dtype="float32",
    shard_attn_heads=False,   # 25 heads vs model=16: shard FFN/SSM dims instead
)
