"""Deterministic synthetic corpus: a topic-conditioned Zipfian Markov
language.

Design goals:
  * fully deterministic from (seed, step, global_row) — a restarted or
    re-sharded job regenerates exactly the same global batch (elastic
    data parallelism without a persisted dataloader state);
  * learnable structure at several ranges so compression quality differences
    are measurable: local bigram structure (affine successor maps), a slowly
    mixing latent *topic* (long-range signal that deepens middle-layer
    information density — the U-shape the paper leans on), and a Zipfian
    unigram floor;
  * O(1) memory — no corpus on disk.

Generative process per token:
  with prob alpha:  t' = (a_j * t + c_j + topic * d) mod V,  j ~ U{0..branch-1}
  else:             t' ~ Zipf(V)
  topic flips to a fresh uniform draw with prob topic_flip per step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4
    alpha: float = 0.85
    n_topics: int = 8
    topic_flip: float = 0.02
    zipf_s: float = 1.2


class SyntheticLM:
    """Vectorized generator. All randomness is counter-based: the stream for
    (step, row) is seeded independently, so sharding/elasticity never change
    the data."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        g = np.random.default_rng(cfg.seed)
        V, B = cfg.vocab_size, cfg.branch
        # affine successor maps (odd multipliers -> bijections mod V)
        self.mult = (g.integers(1, V, size=B) | 1).astype(np.int64)
        self.add = g.integers(0, V, size=B).astype(np.int64)
        self.topic_shift = g.integers(0, V, size=cfg.n_topics).astype(np.int64)
        # zipf pmf over ranks, fixed permutation rank -> token id
        ranks = np.arange(1, V + 1, dtype=np.float64)
        pmf = ranks ** (-cfg.zipf_s)
        self.zipf_cdf = np.cumsum(pmf / pmf.sum())
        self.perm = g.permutation(V)

    def _zipf(self, rng: np.random.Generator, n: int) -> np.ndarray:
        u = rng.random(n)
        r = np.searchsorted(self.zipf_cdf, u)
        return self.perm[np.minimum(r, self.cfg.vocab_size - 1)]

    def sample_rows(self, step: int, rows: np.ndarray) -> np.ndarray:
        """Generate tokens (len(rows), seq_len) for the given global rows of
        the given step. Deterministic in (seed, step, row)."""
        cfg = self.cfg
        n, S, V = len(rows), cfg.seq_len, cfg.vocab_size
        out = np.empty((n, S), dtype=np.int32)
        # one independent counter-based stream per row
        seeds = [np.random.SeedSequence(
            entropy=(cfg.seed, 0x5D7A, step, int(r))) for r in rows]
        rngs = [np.random.default_rng(s) for s in seeds]
        for i, rng in enumerate(rngs):
            t = int(self._zipf(rng, 1)[0])
            topic = int(rng.integers(cfg.n_topics))
            u_branch = rng.integers(0, cfg.branch, size=S)
            u_mix = rng.random(S)
            u_flip = rng.random(S)
            zipf_draws = self._zipf(rng, S)
            new_topics = rng.integers(0, cfg.n_topics, size=S)
            row = out[i]
            for s in range(S):
                if u_flip[s] < cfg.topic_flip:
                    topic = int(new_topics[s])
                if u_mix[s] < cfg.alpha:
                    j = u_branch[s]
                    t = int((self.mult[j] * t + self.add[j]
                             + self.topic_shift[topic]) % V)
                else:
                    t = int(zipf_draws[s])
                row[s] = t
        return out

    # -- entropy floor estimate (for experiment reporting) ------------------
    def entropy_floor(self, n_rows: int = 64, step: int = 10 ** 6) -> float:
        """Monte-Carlo estimate of the per-token conditional entropy (nats)
        of the generative process — the minimum achievable loss."""
        cfg = self.cfg
        # H = alpha*log(branch-ish) + (1-alpha)*H(zipf) + topic noise; do it
        # empirically via the known mixture:
        pmf = np.diff(np.concatenate([[0.0], self.zipf_cdf]))
        h_zipf = float(-(pmf * np.log(np.maximum(pmf, 1e-300))).sum())
        # successor branch: branch equally likely affine maps (distinct
        # targets w.h.p.) -> log(branch); mixture entropy approximation:
        a = cfg.alpha
        h = (a * np.log(cfg.branch) + (1 - a) * h_zipf
             + cfg.topic_flip * np.log(cfg.n_topics))
        return float(h)


class ShardedLoader:
    """Deterministic, elastic DP loader.

    Shard `shard_id`/`num_shards` of step s yields global rows
    [shard_id * B/num_shards, ...) — data depends only on (seed, step, row),
    so checkpoint-restart on a different DP size replays identically.
    """

    def __init__(self, cfg: DataConfig, shard_id: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0, \
            (cfg.global_batch, num_shards)
        self.cfg = cfg
        self.lm = SyntheticLM(cfg)
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def rows_for(self, step: int) -> np.ndarray:
        lo = self.shard_id * self.local_batch
        return np.arange(lo, lo + self.local_batch)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        tokens = self.lm.sample_rows(step, self.rows_for(step))
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def calibration_batches(cfg: DataConfig, n_samples: int, batch_size: int,
                        calib_seed: int = 10_001):
    """The paper's calibration set: `n_samples` sequences (seed-disjoint
    from training steps via a huge step offset)."""
    lm = SyntheticLM(dataclasses.replace(cfg, seed=cfg.seed))
    out = []
    for i in range(0, n_samples, batch_size):
        rows = np.arange(i, min(i + batch_size, n_samples))
        out.append({"tokens": lm.sample_rows(calib_seed, rows)})
    return out
