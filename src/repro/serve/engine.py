"""Serving: jitted prefill + single-token decode steps and a slot-based
continuous-batching driver.

The engine keeps a fixed pool of `batch` decode slots. Requests are admitted
into free slots (their prompt prefilled into that slot's cache region) and
retired when they emit `n_new` tokens; every decode step advances ALL active
slots at once (per-sequence positions — the cache layer supports (B,)
position vectors). Works identically for dense, compressed (factorized),
full-KV, sliding-window, SSM-state and enc-dec models.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.models.params import Params


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8                # decode slot count
    max_len: int = 512            # cache capacity (prompt + generated)
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (S,)
    n_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.plan = None              # set when booted from a compressed ckpt
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(p, cfg, c, t))
        self._prefill_cache: Dict[int, object] = {}
        self.key = jax.random.PRNGKey(scfg.seed)

    @classmethod
    def from_compressed(cls, ckpt_dir: str, cfg: ModelConfig,
                        scfg: ServeConfig, verify: bool = False) -> "Engine":
        """Boot directly from a ``compress.save_plan`` artifact — no
        calibration or SVD at serve time; the factorized list-form params
        drop straight into the model code. ``verify=True`` re-hashes the
        stored arrays against the manifest content hashes first
        (``launch/serve.py --verify``).

        Example (boot from an artifact and generate; continues the
        ``compress.save_plan`` example)::

            >>> import tempfile, jax, numpy as np
            >>> from repro.configs import get_config
            >>> from repro.core import compress as CC
            >>> from repro.models import transformer as T
            >>> from repro.serve.engine import Engine, ServeConfig
            >>> cfg = get_config("llama-mini").replace(
            ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
            ...     head_dim=16, d_ff=64, vocab_size=128, rank_multiple=1)
            >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
            >>> calib = [{"tokens": jax.random.randint(
            ...     jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}]
            >>> comp, plan = CC.build_plan_and_params(
            ...     params, cfg, CC.CompressionConfig(ratio=0.3), calib)
            >>> d = tempfile.mkdtemp()
            >>> _ = CC.save_plan(d, comp, plan, cfg)
            >>> eng = Engine.from_compressed(d, cfg, ServeConfig(),
            ...                              verify=True)
            >>> prompts = np.arange(8, dtype=np.int32).reshape(2, 4)
            >>> eng.generate(prompts, n_new=3).shape
            (2, 3)
        """
        from repro.core import compress as CC
        params, plan = CC.load_plan(ckpt_dir, cfg=cfg, verify=verify)
        eng = cls(params, cfg, scfg)
        eng.plan = plan
        return eng

    # ---- batch generation (simple API, fixed same-length prompts) --------
    def generate(self, prompts: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, n_new)."""
        cfg, scfg = self.cfg, self.scfg
        batch = {"tokens": jnp.asarray(prompts)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        max_len = prompts.shape[1] + n_new + 1
        logits, cache = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))(
                self.params, batch)
        outs = []
        tok = self._sample(logits)
        for _ in range(n_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1] / self.scfg.temperature)[:, None].astype(
                jnp.int32)

    # ---- throughput measurement (Fig. 4 benchmark) ------------------------
    def measure_decode_throughput(self, batch: int, prompt_len: int,
                                  n_new: int, warmup: int = 3
                                  ) -> Dict[str, float]:
        prompts = np.random.default_rng(0).integers(
            0, self.cfg.vocab_size, size=(batch, prompt_len),
            dtype=np.int32)
        b = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encoder_decoder:
            b["enc_embeds"] = jnp.zeros(
                (batch, prompt_len, self.cfg.d_model), dtype=jnp.float32)
        logits, cache = jax.jit(lambda p, bb: T.prefill(
            p, self.cfg, bb, max_len=prompt_len + warmup + n_new + 1))(
                self.params, b)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        # warmup advances the cache (each step decodes a fresh position,
        # like the timed loop) and is safely skippable with warmup=0
        for _ in range(warmup):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return {"tokens_per_s": batch * n_new / dt,
                "ms_per_step": dt / n_new * 1000.0}


def _bucket_len(n: int, max_len: int) -> int:
    """Next power of two ≥ n (floor 2), capped at max_len. Bucketing prompt
    pads means `_prefill1` compiles once per bucket — at most
    ⌈log2(max_len)⌉ shapes — instead of once per distinct prompt length."""
    b = 2
    while b < n:
        b *= 2
    return min(b, max_len)


def _scatter_rows(pool: Dict, src: Dict, slots: jax.Array) -> Dict:
    """One whole-pool update: row j of every `src` cache leaf lands in row
    slots[j] of the pool (runs leaves carry a leading stacked-layer axis,
    so batch is axis 1; `pos` is batch-leading). slots[j] >= pool batch
    drops row j — admission pads with out-of-range slots."""
    runs = jax.tree.map(
        lambda pool_l, src_l: pool_l.at[:, slots].set(
            src_l.astype(pool_l.dtype), mode="drop"),
        pool["runs"], src["runs"])
    pos = pool["pos"].at[slots].set(src["pos"], mode="drop")
    return {"runs": runs, "pos": pos}


class ContinuousBatcher:
    """Slot-based continuous batching on top of per-slot caches.

    Every slot owns one row of a persistent batched cache; decode advances
    all live slots each step. Admission is BATCHED: all waiting requests
    that fit into free slots are prefilled together in one fixed-batch
    call, with prompts right-padded to a power-of-two bucket (per-row
    `lengths` keep ragged rows exact — padded cache slots are zeroed and
    masked). The freshly built rows then land in the pool via a single
    donated multi-row scatter. Retraces of the jitted prefill/decode/
    scatter steps are counted in `stats` — the bucketing invariant
    (≤ ⌈log2(max_len)⌉ prefill traces, 1 decode trace) is load-bearing for
    serving latency and asserted in tests.

    Architectures with recurrent state (ssm/lstm/enc-dec) can't right-pad
    a prompt without corrupting the state, so they take the exact-length
    admission path (one prefill trace per distinct prompt length).
    """

    @classmethod
    def from_compressed(cls, ckpt_dir: str, cfg: ModelConfig,
                        scfg: ServeConfig,
                        verify: bool = False) -> "ContinuousBatcher":
        """Boot the batcher from a saved compressed checkpoint (see
        ``Engine.from_compressed``; ``verify`` checks content hashes)."""
        from repro.core import compress as CC
        params, plan = CC.load_plan(ckpt_dir, cfg=cfg, verify=verify)
        cb = cls(params, cfg, scfg)
        cb.plan = plan
        return cb

    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.plan = None
        self.cache = T.init_cache(cfg, scfg.batch, scfg.max_len)
        self.slots: List[Optional[Request]] = [None] * scfg.batch
        self.tokens = jnp.zeros((scfg.batch, 1), dtype=jnp.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        kinds = {k for k, _ in cfg.layer_runs()}
        self.bucketed = (kinds <= {"attn", "swa"}
                         and not cfg.is_encoder_decoder)
        self.stats: Dict[str, int] = {
            "prefill_retraces": 0, "decode_retraces": 0,
            "scatter_retraces": 0, "admissions": 0, "admitted": 0,
        }

        # trace-time side effects: the counters bump once per jit cache
        # miss (tracing) and never during steady-state dispatch
        def _decode_fn(p, c, t):
            self.stats["decode_retraces"] += 1
            return T.decode_step(p, cfg, c, t)

        def _prefill_fn(p, b):
            self.stats["prefill_retraces"] += 1
            return T.prefill(p, cfg, b, max_len=scfg.max_len)

        def _scatter_fn(pool, src, slots):
            self.stats["scatter_retraces"] += 1
            return _scatter_rows(pool, src, slots)

        self._decode = jax.jit(_decode_fn)
        self._prefill1 = jax.jit(_prefill_fn)
        self._scatter = jax.jit(_scatter_fn, donate_argnums=(0,))

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        n = min(len(free), len(self.queue))
        if not n:
            return
        admit, self.queue = self.queue[:n], self.queue[n:]
        for req in admit:
            # cache rows hold prompt + generated tokens: an over-long
            # prompt keeps its newest max_len-1 tokens (degrade, not crash)
            keep = self.scfg.max_len - 1
            if len(req.tokens) > keep:
                req.tokens = req.tokens[-keep:]
        if self.bucketed:
            self._admit_batched(admit, free[:n])
        else:
            for req, slot in zip(admit, free):
                self._admit_exact(req, slot)
        self.stats["admissions"] += 1
        self.stats["admitted"] += n

    def _admit_batched(self, admit: List[Request], free: List[int]) -> None:
        """All admitted prompts in ONE fixed-batch bucketed prefill."""
        B = self.scfg.batch
        Sb = _bucket_len(max(len(r.tokens) for r in admit),
                         self.scfg.max_len)
        toks = np.zeros((B, Sb), dtype=np.int32)
        lens = np.ones((B,), dtype=np.int32)
        slots = np.full((B,), B, dtype=np.int32)       # B = dropped row
        for j, (req, slot) in enumerate(zip(admit, free)):
            toks[j, :len(req.tokens)] = req.tokens
            lens[j] = len(req.tokens)
            slots[j] = slot
        logits, c1 = self._prefill1(
            self.params, {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray(lens)})
        self.cache = self._scatter(self.cache, c1, jnp.asarray(slots))
        tok = np.asarray(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
        self.tokens = self.tokens.at[jnp.asarray(slots), 0].set(
            jnp.asarray(tok), mode="drop")
        for j, (req, slot) in enumerate(zip(admit, free)):
            req.out.append(int(tok[j]))
            self.slots[slot] = req

    def _admit_exact(self, req: Request, slot: int) -> None:
        """Exact-length single-row admission (recurrent-state archs)."""
        logits, c1 = self._prefill1(
            self.params, {"tokens": jnp.asarray(req.tokens[None, :])})
        self.cache = self._scatter(self.cache, c1,
                                   jnp.asarray([slot], dtype=np.int32))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        req.out.append(int(tok[0]))
        self.tokens = self.tokens.at[slot, 0].set(tok[0])
        self.slots[slot] = req

    def step(self) -> int:
        """One engine iteration: admit + one decode step for all live slots.
        Returns the number of live slots stepped."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        for i in live:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.n_new:
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.slots[i] = None
        return len(live)

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.done
