"""Serving: prefill + single-token decode steps and a slot-based
continuous-batching driver, with a resilience layer (DESIGN.md §5).
All jit dispatch goes through an executable registry (``serve/aot.py``):
lazily traced by default, AOT-compiled from the persistent cache when
booted through ``repro.serve.api`` with ``aot=True`` (DESIGN.md §5.6).

The engine keeps a fixed pool of `batch` decode slots. Requests are admitted
into free slots (their prompt prefilled into that slot's cache region) and
retired when they emit `n_new` tokens; every decode step advances ALL active
slots at once (per-sequence positions — the cache layer supports (B,)
position vectors). Works identically for dense, compressed (factorized),
full-KV, sliding-window, SSM-state and enc-dec models.

Resilience (all opt-in via ``AdmissionConfig`` / constructor kwargs, the
default construction behaves exactly like the pre-resilience engine):

* **admission control** — bounded queue with explicit backpressure
  (``submit`` returns accept/reject), per-request deadlines shed overdue
  work before it wastes a prefill (``serve.admission``).
* **poison quarantine** — every prefill/decode emits through a finite
  guard; non-finite logits rows are attributed (bisected when ambiguous),
  their slots purged (cache row zeroed so later tenants of the slot can
  never attend into poisoned state), and the requests re-queued under a
  bounded retry budget, then failed with a typed error. Healthy slots
  never see a poisoned token.
* **elastic-rank degradation** — with ``elastic=True`` and factorized
  params, the batcher holds a pow2 rank-bucket ladder
  (``compress.slice_rank_ladder``) and drops decode rank under queue
  pressure instead of shedding, restoring it as the queue drains.
  Retrace-free beyond one compile per rung: the KV cache layout is
  rank-independent, so switching rungs just swaps the weight pytree.
* **liveness** — ``run_until_drained`` returns a ``DrainResult`` whose
  ``status`` distinguishes drained / timeout / stalled (watchdog on
  forward progress), and the step loop beats a ``dist.ft.Heartbeat``.
* **fault injection** — a ``dist.faultinject.FaultPlan`` drives
  seed-deterministic NaN/latency/heartbeat faults through the exact same
  code paths production faults would take (chaos suite:
  tests/test_resilience.py).
* **observability** — every stage is traced (``obs.trace`` spans:
  admit/prefill/decode_step/purge/poison_probe, per-request async spans,
  queue-depth and rung counter tracks) and a flight recorder
  (``obs.flightrec``) rings recent events, auto-dumping an artifact on a
  typed request failure or a non-``drained`` drain (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import json
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.models.params import Params
from repro.obs import flightrec as frec
from repro.obs import trace
from repro.serve import admission as adm
from repro.serve import aot as aotlib


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8                # decode slot count
    max_len: int = 512            # cache capacity (prompt + generated)
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0
    # --- paged KV pool (DESIGN.md §5.7) -----------------------------------
    kv_block: int = 0             # KV block size in tokens; 0 = contiguous
    #                               per-slot pool (the historical layout)
    prefix_cache: bool = False    # share identical prompt-prefix blocks
    #                               across requests (requires kv_block > 0)


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (S,)
    n_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0
    # --- resilience fields (serve.admission / quarantine) -----------------
    deadline_s: Optional[float] = None   # relative to submit; None = none
    status: str = adm.QUEUED
    retries: int = 0              # poison-quarantine attempts consumed
    t_admit: float = 0.0
    t_first: float = 0.0          # first token emitted (TTFT anchor)
    error: Optional[str] = None   # set on typed failure
    truncated: bool = False       # prompt lost its oldest tokens at
    #                               admission (over max_len - 1)


class DrainResult(list):
    """``run_until_drained`` result: a list of completed requests (so the
    historical ``done = cb.run_until_drained()`` callers keep working)
    plus the drain verdict.

    ``status`` is ``"drained"`` (queue empty, all slots free),
    ``"timeout"`` (``max_steps`` exhausted with work still pending) or
    ``"stalled"`` (the watchdog saw no forward progress — tokens, shed or
    terminal transitions — for ``watchdog_s``). ``undrained`` lists the
    requests still queued or running; ``shed``/``rejected``/``failed``
    surface the terminal non-success populations."""

    def __init__(self, done: List[Request], status: str,
                 undrained: List[Request], shed: List[Request],
                 rejected: List[Request], failed: List[Request]):
        super().__init__(done)
        self.status = status
        self.undrained = undrained
        self.shed = shed
        self.rejected = rejected
        self.failed = failed


def _normalize_load_retries(retries, load_retries: int) -> int:
    """Fold the pre-API ``retries=`` spelling into ``load_retries=`` (the
    ``repro.serve.api`` name) with a deprecation warning."""
    if retries is not None:
        warnings.warn(
            "from_compressed(retries=...) is deprecated; use "
            "load_retries=... (repro.serve.api spelling)",
            DeprecationWarning, stacklevel=3)
        return int(retries)
    return load_retries


def from_compressed(ckpt_dir: str, cfg: ModelConfig,
                    scfg: Optional[ServeConfig] = None, *,
                    batcher: bool = True, verify: bool = False,
                    load_retries: int = 0,
                    quarantine: Optional[bool] = None,
                    **kwargs):
    """THE loading path for booting serve engines from a
    ``compress.save_plan`` artifact — ``Engine.from_compressed`` and
    ``ContinuousBatcher.from_compressed`` both delegate here (they used
    to carry diverged copies of the manifest handling), and
    ``repro.serve.api`` re-exports it.

    ``verify=True`` re-hashes the stored arrays against the manifest
    content hashes before booting; ``load_retries > 0`` retries a
    transiently failing load with backoff and (with ``quarantine``,
    default: on whenever retries are) moves a persistently failing
    artifact aside before raising a typed ``store.IntegrityError``.
    ``batcher=False`` returns the fixed-batch :class:`Engine` instead of
    the :class:`ContinuousBatcher`; extra kwargs (``admission``,
    ``faults``, ``heartbeat``, ``executables``) pass through to the
    batcher constructor.
    """
    from repro.core import compress as CC
    if quarantine is None:
        quarantine = load_retries > 0
    params, plan = CC.load_plan(ckpt_dir, cfg=cfg, verify=verify,
                                retries=load_retries, quarantine=quarantine)
    scfg = scfg if scfg is not None else ServeConfig()
    cls = ContinuousBatcher if batcher else Engine
    eng = cls(params, cfg, scfg, **kwargs)
    eng.plan = plan
    return eng


class Engine:
    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.plan = None              # set when booted from a compressed ckpt
        self.stats: Dict[str, int] = {"prefill_retraces": 0,
                                      "decode_retraces": 0}

        def _decode_fn(p, c, t):
            self.stats["decode_retraces"] += 1
            return T.decode_step(p, cfg, c, t)

        self._decode = jax.jit(_decode_fn)
        self._prefill_cache: Dict[int, object] = {}
        self.key = jax.random.PRNGKey(scfg.seed)

    def _prefill_fn(self, max_len: int):
        """Memoized jitted prefill per cache capacity. ``generate`` /
        ``measure_decode_throughput`` used to build a fresh ``jax.jit``
        closure every call, so every invocation retraced (and recompiled)
        the whole prefill even at identical shapes; the cache keys on
        ``max_len`` — the only trace-relevant closure capture — and the
        retrace counter makes the bound assertable."""
        fn = self._prefill_cache.get(max_len)
        if fn is None:
            cfg = self.cfg

            def _p(p, b):
                self.stats["prefill_retraces"] += 1
                return T.prefill(p, cfg, b, max_len=max_len)

            fn = jax.jit(_p)
            self._prefill_cache[max_len] = fn
        return fn

    @classmethod
    def from_compressed(cls, ckpt_dir: str, cfg: ModelConfig,
                        scfg: ServeConfig, verify: bool = False,
                        retries: Optional[int] = None,
                        load_retries: int = 0,
                        quarantine: Optional[bool] = None) -> "Engine":
        """Boot directly from a ``compress.save_plan`` artifact — no
        calibration or SVD at serve time; the factorized list-form params
        drop straight into the model code. Delegates to the unified
        module-level :func:`from_compressed` (one loading path for both
        engine flavors, re-exported from ``repro.serve.api``).
        ``verify=True`` re-hashes the stored arrays against the manifest
        content hashes first (``launch/serve.py --verify``).
        ``load_retries``/``quarantine`` retry-with-backoff a transiently
        failing load and move a persistently sha256-failing artifact
        aside before raising a typed ``store.IntegrityError``
        (``--load-retries``); ``retries=`` is the deprecated pre-API
        spelling of ``load_retries=``.

        Example (boot from an artifact and generate; continues the
        ``compress.save_plan`` example)::

            >>> import tempfile, jax, numpy as np
            >>> from repro.configs import get_config
            >>> from repro.core import compress as CC
            >>> from repro.models import transformer as T
            >>> from repro.serve.engine import Engine, ServeConfig
            >>> cfg = get_config("llama-mini").replace(
            ...     n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
            ...     head_dim=16, d_ff=64, vocab_size=128, rank_multiple=1)
            >>> params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
            >>> calib = [{"tokens": jax.random.randint(
            ...     jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}]
            >>> comp, plan = CC.build_plan_and_params(
            ...     params, cfg, CC.CompressionConfig(ratio=0.3), calib)
            >>> d = tempfile.mkdtemp()
            >>> _ = CC.save_plan(d, comp, plan, cfg)
            >>> eng = Engine.from_compressed(d, cfg, ServeConfig(),
            ...                              verify=True)
            >>> prompts = np.arange(8, dtype=np.int32).reshape(2, 4)
            >>> eng.generate(prompts, n_new=3).shape
            (2, 3)
        """
        return from_compressed(
            ckpt_dir, cfg, scfg, batcher=False, verify=verify,
            load_retries=_normalize_load_retries(retries, load_retries),
            quarantine=quarantine)

    # ---- batch generation (simple API, fixed same-length prompts) --------
    def generate(self, prompts: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, n_new)."""
        batch = {"tokens": jnp.asarray(prompts)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        max_len = prompts.shape[1] + n_new + 1
        logits, cache = self._prefill_fn(max_len)(self.params, batch)
        outs = []
        tok = self._sample(logits)
        for _ in range(n_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1] / self.scfg.temperature)[:, None].astype(
                jnp.int32)

    # ---- throughput measurement (Fig. 4 benchmark) ------------------------
    def measure_decode_throughput(self, batch: int, prompt_len: int,
                                  n_new: int, warmup: int = 3
                                  ) -> Dict[str, float]:
        prompts = np.random.default_rng(0).integers(
            0, self.cfg.vocab_size, size=(batch, prompt_len),
            dtype=np.int32)
        b = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encoder_decoder:
            b["enc_embeds"] = jnp.zeros(
                (batch, prompt_len, self.cfg.d_model), dtype=jnp.float32)
        logits, cache = self._prefill_fn(
            prompt_len + warmup + n_new + 1)(self.params, b)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        # warmup advances the cache (each step decodes a fresh position,
        # like the timed loop) and is safely skippable with warmup=0
        for _ in range(warmup):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return {"tokens_per_s": batch * n_new / dt,
                "ms_per_step": dt / n_new * 1000.0}


def _bucket_len(n: int, max_len: int) -> int:
    """Next power of two ≥ n (floor 2), capped at max_len. Bucketing prompt
    pads means `_prefill1` compiles once per bucket — at most
    ⌈log2(max_len)⌉ shapes — instead of once per distinct prompt length."""
    b = 2
    while b < n:
        b *= 2
    return min(b, max_len)


class ContinuousBatcher:
    """Slot-based continuous batching on top of per-slot caches.

    Every slot owns one row of a persistent batched cache; decode advances
    all live slots each step. Admission is BATCHED: all waiting requests
    that fit into free slots are prefilled together in one fixed-batch
    call, with prompts right-padded to a power-of-two bucket (per-row
    `lengths` keep ragged rows exact — padded cache slots are zeroed and
    masked). The freshly built rows then land in the pool via a single
    donated multi-row scatter. Retraces of the jitted prefill/decode/
    scatter steps are counted in `stats` — the bucketing invariant
    (≤ ⌈log2(max_len)⌉ prefill traces, 1 decode trace) is load-bearing for
    serving latency and asserted in tests.

    Architectures with recurrent state (ssm/lstm/enc-dec) can't right-pad
    a prompt without corrupting the state, so they take the exact-length
    admission path (one prefill trace per distinct prompt length).
    """

    @classmethod
    def from_compressed(cls, ckpt_dir: str, cfg: ModelConfig,
                        scfg: ServeConfig, verify: bool = False,
                        retries: Optional[int] = None,
                        load_retries: int = 0,
                        quarantine: Optional[bool] = None,
                        **kwargs) -> "ContinuousBatcher":
        """Boot the batcher from a saved compressed checkpoint. Delegates
        to the unified module-level :func:`from_compressed` (one loading
        path shared with ``Engine``; ``verify`` checks content hashes,
        ``load_retries``/``quarantine`` make the load resilient;
        ``retries=`` is the deprecated pre-API spelling). Extra kwargs
        (``admission``, ``faults``, ``heartbeat``, ``executables``) pass
        through to the constructor."""
        return from_compressed(
            ckpt_dir, cfg, scfg, batcher=True, verify=verify,
            load_retries=_normalize_load_retries(retries, load_retries),
            quarantine=quarantine, **kwargs)

    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig,
                 admission: Optional[adm.AdmissionConfig] = None,
                 faults=None, heartbeat=None, executables=None,
                 flight: Optional[frec.FlightRecorder] = None):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.plan = None
        self.acfg = admission or adm.AdmissionConfig()
        self.faults = faults          # dist.faultinject.FaultPlan or None
        self.heartbeat = heartbeat    # dist.ft.Heartbeat or None
        # always-on event ring; only writes when flight.dump_dir is set
        self.flight = flight if flight is not None else frec.FlightRecorder()
        kinds = {k for k, _ in cfg.layer_runs()}
        self.bucketed = (kinds <= {"attn", "swa"}
                         and not cfg.is_encoder_decoder)
        # --- paged KV pool (DESIGN.md §5.7) -------------------------------
        self.paged = scfg.kv_block > 0
        if scfg.prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires kv_block > 0")
        if self.paged:
            if scfg.max_len % scfg.kv_block:
                raise ValueError(
                    f"kv_block={scfg.kv_block} must divide "
                    f"max_len={scfg.max_len}")
            if kinds != {"attn"} or cfg.is_encoder_decoder:
                raise ValueError(
                    "paged KV cache requires a pure-attention decoder "
                    f"(got layer kinds {sorted(kinds)})")
            from repro.serve import paged as pglib
            self.nb = scfg.max_len // scfg.kv_block
            # worst case every slot holds a full-length row, +1 for the
            # reserved null block — without prefix sharing allocation can
            # never fail; sharing only frees headroom
            self.n_blocks = scfg.batch * self.nb + 1
            self.cache = T.init_cache_paged(cfg, scfg.batch,
                                            self.n_blocks, scfg.kv_block)
            self.pool = pglib.BlockPool(self.n_blocks)
            self.prefix = (pglib.PrefixCache(scfg.kv_block)
                           if scfg.prefix_cache else None)
            self.table = np.zeros((scfg.batch, self.nb), dtype=np.int32)
            self._table_dev = None          # cached device copy
            self._req_blocks: Dict[int, tuple] = {}  # rid -> (held, nshared)
        else:
            self.cache = T.init_cache(cfg, scfg.batch, scfg.max_len)
        self.slots: List[Optional[Request]] = [None] * scfg.batch
        self.tokens = jnp.zeros((scfg.batch, 1), dtype=jnp.int32)
        self.done: List[Request] = []
        self.failed: List[Request] = []
        self._metrics = adm.ServeMetrics()
        self.admission = adm.AdmissionController(self.acfg, self._metrics)
        self._step_idx = 0
        self._progress = 0            # bumps on any forward progress
        # streaming hooks (serve/frontdoor.py): called on the engine
        # thread as tokens are emitted / requests reach terminal states /
        # a quarantine rewinds a request's output
        self.on_token: Optional[Callable[[Request, int], None]] = None
        self.on_terminal: Optional[Callable[[Request], None]] = None
        self.on_rewind: Optional[Callable[[Request], None]] = None
        # elastic-rank ladder: rung 0 is self.params ITSELF (token-identical
        # to the pre-ladder engine); rung ℓ slices the singular-value-
        # ordered factors to the pow2 bucket pow2_ceil(k) >> ℓ. Dense
        # params have no factors to slice — the ladder stays length 1.
        self.level = 0
        if self.acfg.elastic:
            from repro.core.compress import slice_rank_ladder
            self.ladder = slice_rank_ladder(params,
                                            levels=self.acfg.elastic_levels)
            if len(self.ladder) > 1 and self.ladder[1] is params:
                self.ladder = [params]
        else:
            self.ladder = [params]
        self.stats: Dict[str, int] = {
            "prefill_retraces": 0, "decode_retraces": 0,
            "scatter_retraces": 0, "admissions": 0, "admitted": 0,
        }
        # executable registry: all prefill/decode/scatter/purge dispatch
        # goes through one object (serve/aot.py). The default traced
        # registry reproduces the historical lazy-jit behavior (and its
        # retrace counters) exactly; an AotRegistry swaps every entry
        # point for an ahead-of-time compiled executable backed by the
        # persistent cache.
        self.exec = executables if executables is not None \
            else aotlib.TracedRegistry(cfg, scfg)
        self.exec.bind_stats(self.stats)

    def warm_executables(self) -> None:
        """Precompile (or cache-load) the full serving surface for this
        batcher's ladder — a no-op for the traced registry; for an
        ``AotRegistry`` this is the boot step that makes the steady-state
        loop trace-free (see ``repro.serve.api.load_engine``)."""
        self.exec.warm(self.ladder, self.bucketed, paged=self.paged)

    # ---- streaming emission (frontdoor hooks) ----------------------------
    def _emit_token(self, req: Request, tok: int) -> None:
        if self.on_token is not None:
            self.on_token(req, tok)

    def _emit_terminal(self, req: Request) -> None:
        trace.async_end("request", req.rid, status=req.status)
        if self.on_terminal is not None:
            self.on_terminal(req)

    def _emit_rewind(self, req: Request) -> None:
        if self.on_rewind is not None:
            self.on_rewind(req)

    # ---- intake ----------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.admission.queue

    def submit(self, req: Request) -> bool:
        """Offer a request. Returns True iff admitted to the wait queue;
        False means backpressure (queue at ``max_queue`` — the request is
        marked ``shed_queue_full`` and kept in ``admission.rejected``)."""
        trace.async_begin("request", req.rid, n_new=req.n_new,
                          prompt_len=len(req.tokens))
        ok = self.admission.offer(req, time.perf_counter())
        if not ok:
            trace.async_end("request", req.rid, status=req.status)
            self.flight.note("reject", rid=req.rid, status=req.status)
        return ok

    def _params_now(self) -> Params:
        return self.ladder[self.level]

    def _adjust_rank_level(self) -> None:
        depth = len(self.queue)
        prev = self.level
        if (depth >= self.acfg.degrade_above
                and self.level < len(self.ladder) - 1):
            self.level += 1
        elif depth <= self.acfg.restore_below and self.level > 0:
            self.level -= 1
        if self.level != prev:
            trace.instant("rung_transition", frm=prev, to=self.level,
                          queue_depth=depth)
            self.flight.note("rung", frm=prev, to=self.level,
                             queue_depth=depth, step=self._step_idx)

    # ---- admission -------------------------------------------------------
    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        admit, shed = self.admission.take(len(free), time.perf_counter())
        for req in shed:
            self.flight.note("shed", rid=req.rid, status=req.status)
            self._emit_terminal(req)
        admit = [r for r in admit if self._check_length(r)]
        if not admit:
            return
        with trace.span("admit", n=len(admit), level=self.level):
            self.flight.note("admit", rids=[r.rid for r in admit],
                             level=self.level)
            if self.paged:
                n_adm = self._admit_paged(admit, free[:len(admit)])
            elif self.bucketed:
                self._admit_batched(admit, free[:len(admit)])
                n_adm = len(admit)
            else:
                for req, slot in zip(admit, free):
                    self._admit_exact(req, slot)
                n_adm = len(admit)
        self.stats["admissions"] += 1
        self.stats["admitted"] += n_adm

    def _check_length(self, req: Request) -> bool:
        """Over-long prompt policy at admission. Cache rows hold prompt +
        generated tokens, so a prompt can keep at most ``max_len - 1``
        tokens. Default: keep the NEWEST tokens (degrade, not crash) —
        but counted, flight-recorded and flagged on the request's
        terminal result instead of silent. With
        ``AdmissionConfig.reject_overlong`` the request is shed typed
        (``shed_overlong``) before it wastes a prefill."""
        keep = self.scfg.max_len - 1
        n = len(req.tokens)
        if n <= keep:
            return True
        if self.acfg.reject_overlong:
            req.status = adm.SHED_OVERLONG
            self._metrics.bump("shed_overlong")
            self.admission.shed.append(req)
            self.flight.note("shed", rid=req.rid, status=req.status,
                             prompt_len=n, max_len=self.scfg.max_len)
            self._emit_terminal(req)
            self._progress += 1          # terminal transition
            return False
        req.tokens = req.tokens[-keep:]
        req.truncated = True
        self._metrics.bump("prompt_truncations")
        self.flight.note("truncate", rid=req.rid, kept=keep,
                         dropped=n - keep)
        return True

    def _poison_rid_rows(self, reqs: Sequence[Request],
                         last: np.ndarray) -> None:
        """Persistent content-poison injection (FaultPlan.poison_rids):
        corrupt the host-side logits row of marked requests."""
        if self.faults is None:
            return
        for j, req in enumerate(reqs):
            if req is not None and self.faults.rid_is_poison(req.rid):
                last[j] = np.nan

    def _admit_batched(self, admit: List[Request], free: List[int]) -> None:
        """All admitted prompts in ONE fixed-batch bucketed prefill,
        emitted through the finite guard."""
        B = self.scfg.batch
        Sb = _bucket_len(max(len(r.tokens) for r in admit),
                         self.scfg.max_len)
        toks = np.zeros((B, Sb), dtype=np.int32)
        lens = np.ones((B,), dtype=np.int32)
        slots = np.full((B,), B, dtype=np.int32)       # B = dropped row
        for j, (req, slot) in enumerate(zip(admit, free)):
            toks[j, :len(req.tokens)] = req.tokens
            lens[j] = len(req.tokens)
            slots[j] = slot
        with trace.span("prefill", bucket=Sb, n=len(admit),
                        level=self.level):
            logits, c1 = self.exec.prefill(
                self._params_now(), {"tokens": jnp.asarray(toks),
                                     "lengths": jnp.asarray(lens)},
                level=self.level, bucket=Sb)
            self.cache = self.exec.scatter(self.cache, c1,
                                           jnp.asarray(slots))
        last = np.array(logits[:, -1])                 # (B, V) writable host copy
        if self.faults is not None:
            for j in self.faults.prefill_rows_to_poison(
                    self.stats["admissions"], len(admit)):
                last[j] = np.nan
        self._poison_rid_rows(admit + [None] * (B - len(admit)), last)
        finite = np.isfinite(last).all(axis=-1)
        tok = last.argmax(-1).astype(np.int32)
        tok[~finite] = 0
        self.tokens = self.tokens.at[jnp.asarray(slots), 0].set(
            jnp.asarray(tok), mode="drop")
        bad: List[int] = []
        now = time.perf_counter()
        for j, (req, slot) in enumerate(zip(admit, free)):
            if finite[j]:
                req.out.append(int(tok[j]))
                self._emit_token(req, int(tok[j]))
                req.t_first = req.t_first or now
                self._metrics.observe_ttft(now - req.t_submit)
                self.slots[slot] = req
                self._progress += 1
            else:
                bad.append(j)
        if bad:
            ambiguous = len(bad) == len(admit) and len(admit) > 1
            self._purge_slots([free[j] for j in bad])
            self._quarantine([admit[j] for j in bad], ambiguous)

    def _admit_exact(self, req: Request, slot: int) -> None:
        """Exact-length single-row admission (recurrent-state archs)."""
        with trace.span("prefill", exact=len(req.tokens),
                        level=self.level):
            logits, c1 = self.exec.prefill(
                self._params_now(),
                {"tokens": jnp.asarray(req.tokens[None, :])},
                level=self.level)
            self.cache = self.exec.scatter(
                self.cache, c1, jnp.asarray([slot], dtype=np.int32))
        last = np.array(logits[:, -1])
        self._poison_rid_rows([req], last)
        if not np.isfinite(last[0]).all():
            self._purge_slots([slot])
            self._quarantine([req], ambiguous=False)
            return
        t = int(last[0].argmax())
        req.out.append(t)
        self._emit_token(req, t)
        now = time.perf_counter()
        req.t_first = req.t_first or now
        self._metrics.observe_ttft(now - req.t_submit)
        self.tokens = self.tokens.at[slot, 0].set(t)
        self.slots[slot] = req
        self._progress += 1

    # ---- paged admission (DESIGN.md §5.7) --------------------------------
    def _table_jnp(self) -> jax.Array:
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.table)
        return self._table_dev

    def _kv_gauges(self) -> None:
        r = self._metrics.registry
        r.gauge("kv_blocks_in_use").set(self.pool.in_use)
        r.gauge("kv_blocks_peak").set(self.pool.peak_in_use)

    def _admit_paged(self, admit: List[Request], free: List[int]) -> int:
        """Paged admission: plan each request against the prefix cache,
        allocate/refcount its blocks into a table row, COW-fork partial
        matches, then prefill in (at most) two fixed-batch groups —
        fresh rows through the plain bucketed prefill, prefix-extending
        rows through ``prefill_ext`` — and route both results into the
        arena with the table-indirected scatter. Requests the pool can't
        hold (only possible with prefix sharing pinning blocks) requeue
        at the front. Returns the number actually admitted."""
        B = self.scfg.batch
        bk = self.scfg.kv_block
        plans: List[tuple] = []           # (req, slot, start)
        cow_src: List[int] = []
        cow_dst: List[int] = []
        deferred: List[Request] = []
        for req, slot in zip(admit, free):
            if deferred:                  # keep FIFO: defer the rest too
                deferred.append(req)
                continue
            n = len(req.tokens)
            need = -(-min(n + req.n_new, self.scfg.max_len) // bk)
            plan = (self.prefix.plan(req.tokens)
                    if self.prefix is not None else None)
            shared = plan.shared if plan is not None else []
            n_alloc = need - len(shared)
            if self.prefix is not None:
                while not self.pool.can_alloc(n_alloc):
                    if not self.prefix.evict_lru(self.pool):
                        break
                    self._metrics.bump("prefix_evictions")
            fresh = self.pool.alloc(n_alloc)
            if fresh is None:
                deferred.append(req)
                continue
            held = [e.block for e in shared]
            for b in held:
                self.pool.incref(b)
            held.extend(fresh)
            row = np.zeros((self.nb,), dtype=np.int32)
            row[:len(held)] = held
            self.table[slot] = row
            start = 0
            if plan is not None:
                start = plan.start
                if plan.cow_src:
                    cow_src.append(plan.cow_src)
                    cow_dst.append(fresh[0])
                    self._metrics.bump("cow_forks")
                self._metrics.bump(
                    "prefix_hits" if start > 0 else "prefix_misses")
            self._req_blocks[req.rid] = (held, len(shared))
            plans.append((req, slot, start))
        for req in reversed(deferred):
            self.admission.requeue(req)
        if not plans:
            return 0
        self._table_dev = None
        self._kv_gauges()
        if cow_src:
            src = np.full((B,), self.n_blocks, dtype=np.int32)
            dst = np.full((B,), self.n_blocks, dtype=np.int32)
            src[:len(cow_src)] = cow_src
            dst[:len(cow_dst)] = cow_dst
            self.cache = self.exec.copy_blocks(
                self.cache, jnp.asarray(src), jnp.asarray(dst))
        tbl = self._table_jnp()
        g0 = [j for j, p in enumerate(plans) if p[2] == 0]
        g1 = [j for j, p in enumerate(plans) if p[2] > 0]
        last_rows: List[Optional[np.ndarray]] = [None] * len(plans)
        for grp, ext in ((g0, False), (g1, True)):
            if not grp:
                continue
            Sg = _bucket_len(
                max(len(plans[j][0].tokens) - plans[j][2] for j in grp),
                self.scfg.max_len)
            toks = np.zeros((B, Sg), dtype=np.int32)
            lens = np.ones((B,), dtype=np.int32)
            starts = np.zeros((B,), dtype=np.int32)
            slots = np.full((B,), B, dtype=np.int32)    # B = dropped row
            for row, j in enumerate(grp):
                req, slot, start = plans[j]
                t = np.asarray(req.tokens[start:], dtype=np.int32)
                toks[row, :len(t)] = t
                lens[row] = len(t)
                starts[row] = start
                slots[row] = slot
            with trace.span("prefill", bucket=Sg, n=len(grp),
                            level=self.level, ext=ext):
                if ext:
                    # arena gather wants the table row of each BATCH row
                    rtbl = jnp.asarray(
                        self.table[np.minimum(slots, B - 1)])
                    logits, c1 = self.exec.prefill_ext(
                        self._params_now(),
                        {"tokens": jnp.asarray(toks),
                         "lengths": jnp.asarray(lens),
                         "starts": jnp.asarray(starts)},
                        self.cache, rtbl, level=self.level, bucket=Sg)
                else:
                    logits, c1 = self.exec.prefill(
                        self._params_now(),
                        {"tokens": jnp.asarray(toks),
                         "lengths": jnp.asarray(lens)},
                        level=self.level, bucket=Sg)
                self.cache = self.exec.scatter_paged(
                    self.cache, c1, jnp.asarray(slots), tbl,
                    jnp.asarray(starts))
            gl = np.array(logits[:, -1])
            for row, j in enumerate(grp):
                last_rows[j] = gl[row]
        last = np.stack(last_rows)                     # (n_plans, V)
        reqs = [p[0] for p in plans]
        if self.faults is not None:
            for j in self.faults.prefill_rows_to_poison(
                    self.stats["admissions"], len(plans)):
                last[j] = np.nan
        self._poison_rid_rows(reqs, last)
        finite = np.isfinite(last).all(axis=-1)
        tok = last.argmax(-1).astype(np.int32)
        tok[~finite] = 0
        tokj = np.zeros((B,), dtype=np.int32)
        slotj = np.full((B,), B, dtype=np.int32)
        for j, (req, slot, start) in enumerate(plans):
            tokj[j] = tok[j]
            slotj[j] = slot
        self.tokens = self.tokens.at[jnp.asarray(slotj), 0].set(
            jnp.asarray(tokj), mode="drop")
        bad: List[int] = []
        now = time.perf_counter()
        for j, (req, slot, start) in enumerate(plans):
            if finite[j]:
                req.out.append(int(tok[j]))
                self._emit_token(req, int(tok[j]))
                req.t_first = req.t_first or now
                self._metrics.observe_ttft(now - req.t_submit)
                self.slots[slot] = req
                self._progress += 1
                if self.prefix is not None:
                    self.prefix.register(np.asarray(req.tokens),
                                         self.table[slot], self.pool)
            else:
                bad.append(j)
        if bad:
            ambiguous = len(bad) == len(plans) and len(plans) > 1
            self._purge_slots([plans[j][1] for j in bad],
                              [plans[j][0] for j in bad])
            self._quarantine([plans[j][0] for j in bad], ambiguous)
        return len(plans)

    def _host_release(self, rows: List[int], reqs: List[Request],
                      contaminated: bool) -> List[int]:
        """Drop each request's block references and clear its table row.
        ``contaminated`` (poison purge): prefix-cache entries built on
        the request's own (fresh) blocks are evicted first, and every
        block whose refcount hits zero is returned for device zeroing —
        while shared prefix blocks another holder still references
        survive untouched. Clean retirement frees without zeroing (a
        freed block is unreachable: no table row points at it, and
        masked positions contribute exact zeros)."""
        zero: List[int] = []
        for slot, req in zip(rows, reqs):
            held, nshared = self._req_blocks.pop(req.rid, ([], 0))
            if contaminated and self.prefix is not None:
                fresh = held[nshared:]
                if fresh:
                    n = self.prefix.evict_blocks(fresh, self.pool)
                    if n:
                        self._metrics.bump("prefix_evictions", n)
            for b in held:
                if self.pool.decref(b) and contaminated:
                    zero.append(b)
            self.table[slot] = 0
        self._table_dev = None
        self._kv_gauges()
        return zero

    def _release_retired(self, rows: List[int],
                         reqs: List[Request]) -> None:
        """Return a retired request's blocks to the pool (no zeroing) and
        mark its slot row dead (pos = -1) so later decode steps neither
        write through the cleared table row nor emit junk."""
        self._host_release(rows, reqs, contaminated=False)
        B = self.scfg.batch
        pad = np.full((B,), B, dtype=np.int32)
        pad[:len(rows)] = rows
        blk = np.full((B * self.nb,), self.n_blocks, dtype=np.int32)
        self.cache = self.exec.purge_paged(self.cache, jnp.asarray(pad),
                                           jnp.asarray(blk))

    # ---- poison quarantine -----------------------------------------------
    def _purge_slots(self, rows: List[int],
                     reqs: Optional[List[Request]] = None) -> None:
        """Quarantine slot cleanup. Contiguous pool: zero the cache rows
        + next-token entries. Paged pool (``reqs`` required — the block
        bookkeeping is per-request): release the requests' blocks, zero
        exactly the blocks whose refcount hit zero (shared prefix blocks
        another request or the cache still holds are never zeroed — the
        other holders' content is untouched by the poisoned row), and
        mark the rows dead."""
        with trace.span("purge", rows=list(rows)):
            B = self.scfg.batch
            pad = np.full((B,), B, dtype=np.int32)
            pad[:len(rows)] = rows
            jrows = jnp.asarray(pad)
            if self.paged:
                zero = self._host_release(rows, list(reqs or []),
                                          contaminated=True)
                blk = np.full((B * self.nb,), self.n_blocks,
                              dtype=np.int32)
                blk[:len(zero)] = zero
                self.cache = self.exec.purge_paged(self.cache, jrows,
                                                   jnp.asarray(blk))
            else:
                self.cache = self.exec.purge(self.cache, jrows)
            self.tokens = self.tokens.at[jrows, 0].set(0, mode="drop")
        self._metrics.bump("slot_purges", len(rows))

    def _probe(self, reqs: List[Request]) -> np.ndarray:
        """Replay each suspect's (prompt + emitted tokens) in isolation —
        one bucketed prefill, no cache writes — and report per-row
        finiteness. Reuses the admission prefill executables, so probing
        adds no new traces."""
        self._metrics.bump("poison_probes")
        trace.instant("poison_probe", rids=[r.rid for r in reqs])
        seqs = []
        keep = self.scfg.max_len - 1
        for r in reqs:
            s = np.concatenate([np.asarray(r.tokens, dtype=np.int32),
                                np.asarray(r.out, dtype=np.int32)])
            seqs.append(s[-keep:])
        if self.bucketed:
            B = self.scfg.batch
            Sb = _bucket_len(max(len(s) for s in seqs), self.scfg.max_len)
            toks = np.zeros((B, Sb), dtype=np.int32)
            lens = np.ones((B,), dtype=np.int32)
            for j, s in enumerate(seqs):
                toks[j, :len(s)] = s
                lens[j] = len(s)
            logits, _ = self.exec.prefill(
                self._params_now(), {"tokens": jnp.asarray(toks),
                                     "lengths": jnp.asarray(lens)},
                level=self.level, bucket=Sb)
            last = np.array(logits[:, -1])
            self._poison_rid_rows(reqs + [None] * (B - len(reqs)), last)
            return np.isfinite(last).all(axis=-1)[:len(reqs)]
        verdict = np.zeros((len(reqs),), dtype=bool)
        for j, s in enumerate(seqs):
            logits, _ = self.exec.prefill(
                self._params_now(), {"tokens": jnp.asarray(s[None, :])},
                level=self.level)
            last = np.array(logits[:, -1])
            self._poison_rid_rows([reqs[j]], last)
            verdict[j] = bool(np.isfinite(last[0]).all())
        return verdict

    def _bisect_poison(self, reqs: List[Request]
                       ) -> tuple[List[Request], List[Request]]:
        """Attribute an ambiguous (every-live-row non-finite) poison event
        to the offending request(s) by bisection: replay suspects in
        isolation; a subset that still comes back all-bad splits in half
        until single offenders remain. Returns (offenders, collateral)."""
        verdict = self._probe(reqs)
        if verdict.all():
            return [], list(reqs)
        if not verdict.any() and len(reqs) > 1:
            mid = len(reqs) // 2
            o1, c1 = self._bisect_poison(reqs[:mid])
            o2, c2 = self._bisect_poison(reqs[mid:])
            return o1 + o2, c1 + c2
        offenders = [r for r, ok in zip(reqs, verdict) if not ok]
        collateral = [r for r, ok in zip(reqs, verdict) if ok]
        return offenders, collateral

    def _quarantine(self, reqs: List[Request], ambiguous: bool) -> None:
        """Evict poisoned requests: re-queue (front, retry budget) or fail
        typed. ``ambiguous=True`` means every live row was non-finite at
        once — bisect to the offender(s) first; proven-healthy collateral
        re-queues without consuming its retry budget, but only when an
        actual offender was identified (otherwise the event was a
        transient engine fault and everyone pays one retry, so a
        persistently faulty engine still terminates typed instead of
        looping forever)."""
        self._metrics.bump("poison_events")
        self.flight.note("poison", rids=[r.rid for r in reqs],
                         ambiguous=ambiguous, level=self.level,
                         step=self._step_idx)
        offenders, collateral = (self._bisect_poison(reqs) if ambiguous
                                 else (list(reqs), []))
        if not offenders:       # transient: no culprit to exonerate against
            charge, collateral = collateral, []
        else:
            charge = offenders
        for req in collateral:
            req.out = []
            req.t_first = 0.0
            self._emit_rewind(req)
            self.admission.requeue(req)
        for req in charge:
            req.retries += 1
            self._metrics.bump("poison_retries")
            if req.retries > self.acfg.max_retries:
                req.status = adm.FAILED_POISON
                req.error = (f"non-finite logits after {req.retries} "
                             f"attempts (retry budget "
                             f"{self.acfg.max_retries})")
                req.t_done = time.perf_counter()
                self.failed.append(req)
                self._metrics.bump("poison_failures")
                self._progress += 1          # terminal transition
                self.flight.note("fail", rid=req.rid, level=self.level,
                                 retries=req.retries, error=req.error)
                self.dump_flight("failed_poison",
                                 {"rid": req.rid, "error": req.error})
                self._emit_terminal(req)
            else:
                req.out = []
                req.t_first = 0.0
                self._emit_rewind(req)
                self.admission.requeue(req)

    # ---- step loop -------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: beat liveness, shed overdue work, admit,
        one decode step for all live slots through the finite guard.
        Returns the number of healthy live slots stepped."""
        t0 = time.perf_counter()
        with trace.span("engine_step", step=self._step_idx):
            n = self._step_inner()
        wall_ms = (time.perf_counter() - t0) * 1e3
        self._metrics.observe_step_ms(wall_ms)
        self.flight.step_timing(self._step_idx - 1, wall_ms, n)
        return n

    def _step_inner(self) -> int:
        idx = self._step_idx
        self._step_idx += 1
        if self.heartbeat is not None:
            self.heartbeat.beat(idx)
        if self.faults is not None:
            if self.faults.wedged(idx):
                return 0                     # hung engine: no progress
            stall = self.faults.stall_for(idx)
            if stall:
                time.sleep(stall)
        self._adjust_rank_level()
        self._metrics.step_at_level(self.level)
        self._metrics.observe_queue_depth(len(self.queue))
        trace.counter("serve", queue_depth=len(self.queue),
                      rank_level=self.level)
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        with trace.span("decode_step", step=idx, live=len(live),
                        level=self.level):
            if self.paged:
                logits, self.cache = self.exec.decode_paged(
                    self._params_now(), self.cache, self.tokens,
                    self._table_jnp(), level=self.level)
            else:
                logits, self.cache = self.exec.decode(
                    self._params_now(), self.cache, self.tokens,
                    level=self.level)
        last = np.array(logits[:, -1])                 # (B, V) writable host copy
        if self.faults is not None:
            for row in self.faults.decode_rows_to_poison(idx, live):
                last[row] = np.nan
        self._poison_rid_rows(self.slots, last)
        finite = np.isfinite(last).all(axis=-1)
        nxt = last.argmax(-1).astype(np.int32)
        good = [i for i in live if finite[i]]
        bad = [i for i in live if not finite[i]]
        nxt[~finite] = 0                     # poisoned tokens never emitted
        self.tokens = jnp.asarray(nxt[:, None])
        retired_rows: List[int] = []
        retired_reqs: List[Request] = []
        for i in good:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            self._emit_token(req, int(nxt[i]))
            self._progress += 1
            if len(req.out) >= req.n_new:
                req.t_done = time.perf_counter()
                req.status = adm.DONE
                self._metrics.bump("completed")
                self.done.append(req)
                self.slots[i] = None
                if self.paged:
                    retired_rows.append(i)
                    retired_reqs.append(req)
                self._emit_terminal(req)
        if retired_rows:
            self._release_retired(retired_rows, retired_reqs)
        if bad:
            ambiguous = len(bad) == len(live) and len(live) > 1
            reqs = [self.slots[i] for i in bad]
            for i in bad:
                self.slots[i] = None
            self._purge_slots(bad, reqs)
            self._quarantine(reqs, ambiguous)
        return len(good)

    def run_until_drained(self, max_steps: int = 100000,
                          watchdog_s: Optional[float] = None
                          ) -> DrainResult:
        """Step until the queue and slots drain. Returns a ``DrainResult``
        (list of completed requests + ``status``): ``"drained"`` on a
        clean drain, ``"timeout"`` when ``max_steps`` is exhausted with
        work still pending (the old silent-return failure mode), and
        ``"stalled"`` when ``watchdog_s`` elapses with no forward
        progress (no token emitted, nothing shed or failed) — a wedged
        engine is reported, not spun on."""
        status = "drained"
        last_progress = time.perf_counter()
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            before = (self._progress
                      + self._metrics.count("shed_deadline"))
            self.step()
            now = time.perf_counter()
            if (self._progress
                    + self._metrics.count("shed_deadline")) > before:
                last_progress = now
            elif (watchdog_s is not None
                    and now - last_progress > watchdog_s):
                status = "stalled"
                break
        else:
            status = "timeout"
        undrained = ([r for r in self.slots if r is not None]
                     + list(self.queue))
        if status == "timeout" and not undrained:
            status = "drained"     # last permitted step finished the work
        if status != "drained":
            self.dump_flight(status,
                             {"undrained_rids": [r.rid for r in undrained]})
        return DrainResult(self.done, status, undrained,
                           shed=list(self.admission.shed),
                           rejected=list(self.admission.rejected),
                           failed=list(self.failed))

    # ---- observability ---------------------------------------------------
    def metrics(self) -> Dict:
        """The structured serve-metrics snapshot (v2 schema + deprecated
        legacy aliases: queue depth, shed counts, retries, rank-bucket
        residency, TTFT/queue-wait percentiles, jit retrace + AOT
        counters) — the one surface shared by operators
        (``serve.py --stats-json``), the degradation benchmark and the
        chaos tests."""
        return self._metrics.snapshot(len(self.queue), self.level,
                                      engine_stats=self.stats)

    def dump_flight(self, reason: str,
                    extra: Optional[Dict] = None) -> Optional[str]:
        """Dump the flight-recorder ring with full engine context (armed
        ``FaultPlan`` incl. seed, queue/slot state, elastic rung, step
        index). Returns the artifact path, or ``None`` when no dump dir
        is configured. Called automatically on a typed poison failure and
        a non-``drained`` drain; the front door calls it on its own
        triggers too."""
        ctx: Dict = {
            "step": self._step_idx,
            "rank_level": self.level,
            "ladder_len": len(self.ladder),
            "queue_depth": len(self.queue),
            "queued_rids": [r.rid for r in self.queue],
            "slot_rids": [r.rid if r is not None else None
                          for r in self.slots],
            "failed_rids": [r.rid for r in self.failed],
            "fault_plan": (json.loads(self.faults.to_json())
                           if self.faults is not None else None),
        }
        if extra:
            ctx.update(extra)
        return self.flight.dump(reason, ctx)
