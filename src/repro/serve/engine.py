"""Serving: jitted prefill + single-token decode steps and a slot-based
continuous-batching driver.

The engine keeps a fixed pool of `batch` decode slots. Requests are admitted
into free slots (their prompt prefilled into that slot's cache region) and
retired when they emit `n_new` tokens; every decode step advances ALL active
slots at once (per-sequence positions — the cache layer supports (B,)
position vectors). Works identically for dense, compressed (factorized),
full-KV, sliding-window, SSM-state and enc-dec models.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.models.params import Params


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8                # decode slot count
    max_len: int = 512            # cache capacity (prompt + generated)
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt (S,)
    n_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(p, cfg, c, t))
        self._prefill_cache: Dict[int, object] = {}
        self.key = jax.random.PRNGKey(scfg.seed)

    # ---- batch generation (simple API, fixed same-length prompts) --------
    def generate(self, prompts: np.ndarray, n_new: int,
                 enc_embeds: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, S) int32. Returns (B, n_new)."""
        cfg, scfg = self.cfg, self.scfg
        batch = {"tokens": jnp.asarray(prompts)}
        if enc_embeds is not None:
            batch["enc_embeds"] = jnp.asarray(enc_embeds)
        max_len = prompts.shape[1] + n_new + 1
        logits, cache = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=max_len))(
                self.params, batch)
        outs = []
        tok = self._sample(logits)
        for _ in range(n_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits)
        return np.concatenate([np.asarray(t) for t in outs], axis=1)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits[:, -1] / self.scfg.temperature)[:, None].astype(
                jnp.int32)

    # ---- throughput measurement (Fig. 4 benchmark) ------------------------
    def measure_decode_throughput(self, batch: int, prompt_len: int,
                                  n_new: int, warmup: int = 3
                                  ) -> Dict[str, float]:
        prompts = np.random.default_rng(0).integers(
            0, self.cfg.vocab_size, size=(batch, prompt_len),
            dtype=np.int32)
        b = {"tokens": jnp.asarray(prompts)}
        if self.cfg.is_encoder_decoder:
            b["enc_embeds"] = jnp.zeros(
                (batch, prompt_len, self.cfg.d_model), dtype=jnp.float32)
        logits, cache = jax.jit(lambda p, bb: T.prefill(
            p, self.cfg, bb, max_len=prompt_len + n_new + 1))(self.params, b)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        for _ in range(warmup):
            lg, cache2 = self._decode(self.params, cache, tok)
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        for _ in range(n_new):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        return {"tokens_per_s": batch * n_new / dt,
                "ms_per_step": dt / n_new * 1000.0}


class ContinuousBatcher:
    """Slot-based continuous batching on top of per-slot caches.

    Every slot owns one row of a persistent batched cache. Prompts are
    prefilled slot-by-slot (row-scattered into the pool); decode advances
    all live slots each step. This is the deployment-shaped serving loop —
    on a real cluster the prefill would run on a disaggregated prefill pod.
    """

    def __init__(self, params: Params, cfg: ModelConfig, scfg: ServeConfig):
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.cache = T.init_cache(cfg, scfg.batch, scfg.max_len)
        self.slots: List[Optional[Request]] = [None] * scfg.batch
        self.tokens = jnp.zeros((scfg.batch, 1), dtype=jnp.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._decode = jax.jit(lambda p, c, t: T.decode_step(p, cfg, c, t))
        self._prefill1 = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, max_len=scfg.max_len))

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.scfg.batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, c1 = self._prefill1(
                self.params, {"tokens": jnp.asarray(req.tokens[None, :])})
            # scatter the single-row cache into this slot of the pool
            self.cache = jax.tree.map(
                lambda pool, single: _scatter_row(pool, single, slot),
                self.cache, c1)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            req.out.append(int(tok[0]))
            self.tokens = self.tokens.at[slot, 0].set(tok[0])
            self.slots[slot] = req

    def step(self) -> int:
        """One engine iteration: admit + one decode step for all live slots.
        Returns the number of live slots stepped."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.tokens)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        for i in live:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.n_new:
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.slots[i] = None
        return len(live)

    def run_until_drained(self, max_steps: int = 100000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.done


def _scatter_row(pool, single, slot: int):
    """Insert a batch-1 cache subtree into row `slot` of the pooled cache.
    Handles leading stacked-layer dims: the batch axis is the one where
    pool.shape differs from single.shape."""
    if not hasattr(pool, "shape") or pool.ndim == 0:
        return pool
    for ax in range(pool.ndim):
        if ax < single.ndim and pool.shape[ax] != single.shape[ax] \
                and single.shape[ax] == 1:
            idx = [slice(None)] * pool.ndim
            idx[ax] = slot
            src = jnp.squeeze(single, axis=ax)
            return pool.at[tuple(idx)].set(src.astype(pool.dtype))
    # slot-pool of size 1: shapes coincide; row 0 is the only slot
    if pool.shape == single.shape and pool.shape and pool.shape[0] == 1 \
            and slot == 0:
        return single.astype(pool.dtype)
    return pool
