"""AOT-compiled serve executables with a persistent on-disk cache
(DESIGN.md §5.6).

Boot used to pay jit tracing for every prefill bucket plus the decode
step the first time each shape arrived — a pod restart under load was a
latency cliff of several seconds before the first token. This module
makes the serve executables an explicit, ahead-of-time-compiled
*registry*:

* **ExecutableRegistry** is the one dispatch surface the engine calls
  (``decode`` / ``prefill`` / ``scatter`` / ``purge``). Two
  implementations share it:

  - ``TracedRegistry`` — the historical behavior: one ``jax.jit``
    closure per role, compiled lazily on first use, with the batcher's
    ``*_retraces`` counters bumped at trace time (the bucketing
    invariant tests assert on them).
  - ``AotRegistry`` — every entry point is lowered and compiled
    explicitly (``jax.jit(...).lower(avals).compile()``) and the
    compiled executable is **persisted** via
    ``jax.experimental.serialize_executable``. ``warm()`` precompiles
    the whole serving surface at boot — the decode step for every
    elastic-rank rung, every pow2 prefill bucket, and the scatter/purge
    cache helpers — so the steady-state loop never traces.

* **AotCache** is the persistent store: one file per executable under a
  cache directory, keyed by sha256 of (artifact fingerprint ×
  ServeConfig × model fingerprint × jax/jaxlib version × backend ×
  entry signature). A second boot of the same artifact deserializes
  instead of compiling (``aot_compiles == 0``), reaching the first
  token in a fraction of the tracing boot (``benchmarks/boot_ttft.py``
  records the ratio). Any mismatch — different artifact fingerprint,
  different jax version, a corrupt or truncated cache file — simply
  misses and falls back to a fresh compile; the cache can never change
  results, only skip work.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.obs import trace

# Roles an engine dispatches through the registry. One compiled
# executable exists per (role, variant): decode has one variant per
# elastic-rank rung, prefill one per (rung, bucket), the cache helpers
# one per source batch width.
ROLE_DECODE = "decode"
ROLE_PREFILL = "prefill"
ROLE_SCATTER = "scatter"
ROLE_PURGE = "purge"
# paged-pool roles (DESIGN.md §5.7); only live when ServeConfig.kv_block > 0
ROLE_DECODE_PAGED = "decode_paged"
ROLE_PREFILL_EXT = "prefill_ext"
ROLE_SCATTER_PAGED = "scatter_paged"
ROLE_PURGE_PAGED = "purge_paged"
ROLE_COPY_BLOCKS = "copy_blocks"

AOT_STAT_KEYS = ("aot_compiles", "aot_cache_hits", "aot_deser_failures",
                 "aot_fallbacks")


def default_cache_dir() -> str:
    """Resolution order: ``$REPRO_AOT_CACHE`` then ``~/.cache/repro/aot``."""
    return os.environ.get(
        "REPRO_AOT_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "aot"))


# ---------------------------------------------------------------------------
# Cache-row helpers (shared by both registries; the engine used to keep
# private copies of these as inline jit closures)
# ---------------------------------------------------------------------------
def scatter_rows(pool: Dict, src: Dict, slots: jax.Array) -> Dict:
    """One whole-pool update: row j of every `src` cache leaf lands in row
    slots[j] of the pool (runs leaves carry a leading stacked-layer axis,
    so batch is axis 1; `pos` is batch-leading). slots[j] >= pool batch
    drops row j — admission pads with out-of-range slots."""
    runs = jax.tree.map(
        lambda pool_l, src_l: pool_l.at[:, slots].set(
            src_l.astype(pool_l.dtype), mode="drop"),
        pool["runs"], src["runs"])
    pos = pool["pos"].at[slots].set(src["pos"], mode="drop")
    return {"runs": runs, "pos": pos}


def purge_rows(pool: Dict, rows: jax.Array) -> Dict:
    """Zero cache rows + positions of quarantined slots so the next tenant
    (or a masked-out dead region) can never attend into poisoned state;
    rows >= batch are padding (dropped)."""
    runs = jax.tree.map(
        lambda leaf: leaf.at[:, rows].set(0, mode="drop"), pool["runs"])
    pos = pool["pos"].at[rows].set(-1, mode="drop")
    return {"runs": runs, "pos": pos}


def scatter_paged(pool: Dict, src: Dict, slots: jax.Array,
                  table: jax.Array, starts: jax.Array) -> Dict:
    """Paged-pool admission write: route each freshly-prefilled row of
    ``src`` (leaves (n, B, S, KV, hd)) through the block table into the
    flat arena (leaves (n, P, bk, KV, hd)). Row j's token i lands at
    absolute position starts[j] + i, i.e. physical block
    table[slots[j], absp // bk], offset absp % bk. Out-of-range slots
    (padding), positions past the table, and null-block (0) table
    entries all resolve to the arena-size sentinel and are dropped —
    shared prefix blocks below ``starts`` are never written."""
    nrows, NB = table.shape
    S = jax.tree.leaves(src["runs"])[0].shape[2]
    i = jnp.arange(S)[None, :]                           # (1, S)
    absp = starts[:, None] + i                           # (B, S)
    tail = (src["pos"] - starts)[:, None]
    srow = jnp.minimum(slots, nrows - 1)

    def _leaf(pool_l, src_l):
        P, bk = pool_l.shape[1], pool_l.shape[2]
        blk = absp // bk
        ok = (i < tail) & (slots[:, None] < nrows) & (blk < NB)
        tb = table[srow[:, None], jnp.minimum(blk, NB - 1)]
        pb = jnp.where(ok & (tb > 0), tb, P)             # P = drop sentinel
        return pool_l.at[:, pb, absp % bk].set(
            src_l.astype(pool_l.dtype), mode="drop")

    runs = jax.tree.map(_leaf, pool["runs"], src["runs"])
    pos = pool["pos"].at[slots].set(src["pos"], mode="drop")
    return {"runs": runs, "pos": pos}


def purge_paged(pool: Dict, rows: jax.Array, blocks: jax.Array) -> Dict:
    """Paged quarantine/retirement: zero the listed *arena blocks* (only
    those whose refcount hit zero — shared prefix blocks another request
    still holds are never listed, the host allocator guarantees it) and
    mark the listed slot rows dead (pos = -1, dropping their decode
    writes and zeroing their outputs). Both arrays are fixed-width with
    out-of-range sentinels (arena size / batch) for padding."""
    runs = jax.tree.map(
        lambda leaf: leaf.at[:, blocks].set(0, mode="drop"), pool["runs"])
    pos = pool["pos"].at[rows].set(-1, mode="drop")
    return {"runs": runs, "pos": pos}


def copy_blocks(pool: Dict, src: jax.Array, dst: jax.Array) -> Dict:
    """Copy-on-write fork: arena block src[j] → dst[j] for each j. The
    destination blocks are freshly allocated (refcount 1, unshared), so
    this is the only write a shared block's content ever feeds. Sentinel
    entries (>= arena size) are dropped (gathers clamp harmlessly)."""
    def _leaf(leaf):
        s = jnp.minimum(src, leaf.shape[1] - 1)
        return leaf.at[:, dst].set(leaf[:, s], mode="drop")
    return {"runs": jax.tree.map(_leaf, pool["runs"]), "pos": pool["pos"]}


# ---------------------------------------------------------------------------
# Fingerprints & cache keys
# ---------------------------------------------------------------------------
def live_fingerprint(params, cfg: ModelConfig) -> str:
    """Fingerprint for an in-memory (non-artifact) boot: the param tree's
    structure + leaf shapes/dtypes and the model dims. Weights are jit
    *arguments*, so the executables depend only on shapes — but keying on
    the artifact identity (see ``ckpt.store.artifact_fingerprint`` for
    saved artifacts) keeps invalidation semantics trivially safe."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h = hashlib.sha256()
    h.update(str(treedef).encode())
    for leaf in leaves:
        h.update(str(jnp.shape(leaf)).encode())
        h.update(str(getattr(leaf, "dtype", type(leaf))).encode())
    h.update(json.dumps({"name": cfg.name, "n_layers": cfg.n_layers,
                         "d_model": cfg.d_model,
                         "vocab_size": cfg.vocab_size},
                        sort_keys=True).encode())
    return "live-" + h.hexdigest()[:32]


def _sig_of(args) -> str:
    """Canonical signature of a call: treedef + flat avals. Part of the
    disk key, so executables can never be replayed against a different
    input structure."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    parts = [str(treedef)]
    for leaf in leaves:
        parts.append(f"{jnp.shape(leaf)}:{getattr(leaf, 'dtype', '?')}")
    return ";".join(parts)


def cache_key(fingerprint: str, role: str, variant: Tuple, sig: str,
              scfg, cfg: ModelConfig) -> str:
    """sha256 over everything that could change the compiled executable:
    artifact fingerprint, serve + model config, jax/jaxlib version and
    backend, and the entry's (role, variant, aval signature)."""
    payload = {
        "fingerprint": fingerprint,
        "role": role,
        "variant": list(variant),
        "sig": sig,
        "scfg": {"batch": scfg.batch, "max_len": scfg.max_len,
                 "kv_block": getattr(scfg, "kv_block", 0)},
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "vocab_size": cfg.vocab_size,
                  "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                  "dtype": str(cfg.dtype)},
        "jax": jax.__version__,
        "jaxlib": getattr(jax, "jaxlib_version", ""),
        "backend": jax.default_backend(),
    }
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


class AotCache:
    """Directory of serialized compiled executables, one ``<key>.aotx``
    file per entry (pickle of ``serialize_executable.serialize`` output:
    the XLA executable bytes plus in/out pytree defs). Writes are atomic
    (tmp + rename) so a crashed boot never leaves a torn entry; reads
    treat *any* failure — missing file, bad pickle, an executable built
    by an incompatible jax/backend — as a miss."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def path(self, key: str) -> str:
        return os.path.join(self.dir, f"{key}.aotx")

    def load(self, key: str):
        """Deserialize the executable for ``key`` or return ``None`` on
        any miss/corruption (the caller recompiles)."""
        from jax.experimental.serialize_executable import (
            deserialize_and_load)
        p = self.path(key)
        if not os.path.exists(p):
            return None
        try:
            with open(p, "rb") as f:
                payload, in_tree, out_tree = pickle.loads(f.read())
            return deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            return False          # present but unusable: count separately

    def has(self, key: str) -> bool:
        return os.path.exists(self.path(key))

    def store(self, key: str, compiled) -> None:
        from jax.experimental.serialize_executable import serialize
        try:
            blob = pickle.dumps(serialize(compiled))
        except Exception:
            return                # unserializable backend: cache disabled
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, self.path(key))

    def keys(self) -> List[str]:
        return sorted(f[:-5] for f in os.listdir(self.dir)
                      if f.endswith(".aotx"))


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------
class TracedRegistry:
    """The pre-AOT behavior as a registry: one lazily-traced ``jax.jit``
    per role. Trace-time side effects bump the engine's historical
    retrace counters (``prefill_retraces`` / ``decode_retraces`` /
    ``scatter_retraces``) exactly as before — the bucketing invariant
    (≤ ⌈log2(max_len)⌉ prefill traces, 1 decode trace per rung) is
    load-bearing for serving latency and asserted in tests."""

    kind = "traced"

    def __init__(self, cfg: ModelConfig, scfg, stats: Optional[Dict] = None):
        from repro.models import transformer as T
        self.cfg, self.scfg = cfg, scfg
        self.stats = stats if stats is not None else {}
        for k in ("prefill_retraces", "decode_retraces", "scatter_retraces"):
            self.stats.setdefault(k, 0)

        def _decode_fn(p, c, t):
            self.stats["decode_retraces"] += 1
            return T.decode_step(p, cfg, c, t)

        def _prefill_fn(p, b):
            self.stats["prefill_retraces"] += 1
            return T.prefill(p, cfg, b, max_len=scfg.max_len)

        def _scatter_fn(pool, src, slots):
            self.stats["scatter_retraces"] += 1
            return scatter_rows(pool, src, slots)

        def _decode_paged_fn(p, c, t, tbl):
            self.stats["decode_retraces"] += 1
            return T.decode_step(p, cfg, c, t, table=tbl)

        def _prefill_ext_fn(p, b, arena, tbl):
            self.stats["prefill_retraces"] += 1
            return T.prefill_ext(p, cfg, b, arena, tbl)

        def _scatter_paged_fn(pool, src, slots, tbl, starts):
            self.stats["scatter_retraces"] += 1
            return scatter_paged(pool, src, slots, tbl, starts)

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(_prefill_fn)
        self._scatter = jax.jit(_scatter_fn, donate_argnums=(0,))
        self._purge = jax.jit(purge_rows, donate_argnums=(0,))
        self._decode_paged = jax.jit(_decode_paged_fn)
        # the arena rides along read-only (prefix gathers); not donated
        self._prefill_ext = jax.jit(_prefill_ext_fn)
        self._scatter_paged = jax.jit(_scatter_paged_fn, donate_argnums=(0,))
        self._purge_paged = jax.jit(purge_paged, donate_argnums=(0,))
        self._copy_blocks = jax.jit(copy_blocks, donate_argnums=(0,))

    def bind_stats(self, stats: Dict) -> None:
        """Fold any counts accumulated so far into ``stats`` and make it
        the live counter dict (the engine owns one stats surface)."""
        for k, v in self.stats.items():
            stats[k] = stats.get(k, 0) + v
        self.stats = stats

    # role dispatch — variant hints are accepted (and ignored) so the
    # engine calls both registries identically
    def decode(self, params, cache, tokens, *, level: int = 0):
        return self._decode(params, cache, tokens)

    def prefill(self, params, batch, *, level: int = 0, bucket=None):
        return self._prefill(params, batch)

    def scatter(self, pool, src, slots):
        return self._scatter(pool, src, slots)

    def purge(self, pool, rows):
        return self._purge(pool, rows)

    def decode_paged(self, params, cache, tokens, table, *, level: int = 0):
        return self._decode_paged(params, cache, tokens, table)

    def prefill_ext(self, params, batch, arena, table, *, level: int = 0,
                    bucket=None):
        return self._prefill_ext(params, batch, arena, table)

    def scatter_paged(self, pool, src, slots, table, starts):
        return self._scatter_paged(pool, src, slots, table, starts)

    def purge_paged(self, pool, rows, blocks):
        return self._purge_paged(pool, rows, blocks)

    def copy_blocks(self, pool, src, dst):
        return self._copy_blocks(pool, src, dst)

    def warm(self, ladder: Sequence, bucketed: bool,
             paged: bool = False) -> None:
        """No-op: the traced registry compiles lazily, on first use."""


class AotRegistry:
    """AOT-compiled serve executables behind the same role interface.

    Every dispatch resolves (role, variant) → a compiled executable:
    first from the in-memory table, then from the persistent
    ``AotCache`` (deserialization, ~ms), and only then by an explicit
    ``jax.jit(...).lower(avals).compile()`` whose result is written back
    to the cache. ``warm()`` resolves the entire serving surface up
    front from abstract avals — nothing runs, nothing traces lazily
    afterwards, and a warm cache makes boot O(deserialize) instead of
    O(compile).

    Fallback ladder (nothing here can change results, only cost): a
    cache file that is missing/corrupt/incompatible → compile; a loaded
    executable that rejects the actual runtime avals (``TypeError``) →
    recompile from the live arguments and replace the entry
    (``aot_fallbacks``)."""

    kind = "aot"

    def __init__(self, cfg: ModelConfig, scfg, fingerprint: str,
                 cache_dir: Optional[str] = None,
                 stats: Optional[Dict] = None):
        from repro.models import transformer as T
        self._T = T
        self.cfg, self.scfg = cfg, scfg
        self.fingerprint = fingerprint
        self.cache = AotCache(cache_dir or default_cache_dir())
        self.stats = stats if stats is not None else {}
        for k in AOT_STAT_KEYS:
            self.stats.setdefault(k, 0)
        # the engine's traced-era counters stay present (and zero) so the
        # metrics schema is identical across registries
        for k in ("prefill_retraces", "decode_retraces", "scatter_retraces"):
            self.stats.setdefault(k, 0)
        self._mem: Dict[Tuple, Any] = {}

    def bind_stats(self, stats: Dict) -> None:
        for k, v in self.stats.items():
            stats[k] = stats.get(k, 0) + v
        self.stats = stats

    # ---- role functions --------------------------------------------------
    def _role_fn(self, role: str):
        cfg, scfg = self.cfg, self.scfg
        if role == ROLE_DECODE:
            return lambda p, c, t: self._T.decode_step(p, cfg, c, t), ()
        if role == ROLE_PREFILL:
            return (lambda p, b: self._T.prefill(p, cfg, b,
                                                 max_len=scfg.max_len), ())
        if role == ROLE_SCATTER:
            return scatter_rows, (0,)
        if role == ROLE_PURGE:
            return purge_rows, (0,)
        if role == ROLE_DECODE_PAGED:
            return (lambda p, c, t, tbl: self._T.decode_step(
                p, cfg, c, t, table=tbl), ())
        if role == ROLE_PREFILL_EXT:
            return (lambda p, b, arena, tbl: self._T.prefill_ext(
                p, cfg, b, arena, tbl), ())
        if role == ROLE_SCATTER_PAGED:
            return scatter_paged, (0,)
        if role == ROLE_PURGE_PAGED:
            return purge_paged, (0,)
        if role == ROLE_COPY_BLOCKS:
            return copy_blocks, (0,)
        raise KeyError(role)

    # ---- resolution ------------------------------------------------------
    def _resolve(self, role: str, variant: Tuple, args: Tuple):
        """(role, variant) → compiled executable, via memo → disk →
        compile. ``args`` may mix concrete arrays and ShapeDtypeStructs —
        only shapes/dtypes matter for lowering."""
        memk = (role, variant)
        exe = self._mem.get(memk)
        if exe is not None:
            return exe
        fn, donate = self._role_fn(role)
        key = cache_key(self.fingerprint, role, variant, _sig_of(args),
                        self.scfg, self.cfg)
        with trace.span("aot_deserialize", role=role,
                        variant=list(variant)):
            exe = self.cache.load(key)
        if exe is False:
            self.stats["aot_deser_failures"] += 1
            exe = None
        if exe is None:
            with trace.span("aot_compile", role=role,
                            variant=list(variant)):
                compiled = jax.jit(fn, donate_argnums=donate
                                   ).lower(*args).compile()
            self.stats["aot_compiles"] += 1
            self.cache.store(key, compiled)
            exe = compiled
        else:
            self.stats["aot_cache_hits"] += 1
        self._mem[memk] = exe
        return exe

    def _call(self, role: str, variant: Tuple, *args):
        exe = self._resolve(role, variant, args)
        try:
            return exe(*args)
        except TypeError:
            # aval drift (e.g. a weak-typed scalar from a caller we don't
            # control): recompile against the live arguments and swap the
            # entry — degraded to a compile, never to a wrong answer
            self.stats["aot_fallbacks"] += 1
            fn, donate = self._role_fn(role)
            compiled = jax.jit(fn, donate_argnums=donate
                               ).lower(*args).compile()
            self.stats["aot_compiles"] += 1
            self._mem[(role, variant)] = compiled
            return compiled(*args)

    # ---- role dispatch ---------------------------------------------------
    def decode(self, params, cache, tokens, *, level: int = 0):
        return self._call(ROLE_DECODE, (level,), params, cache, tokens)

    def prefill(self, params, batch, *, level: int = 0, bucket=None):
        if bucket is None:         # exact-length path (recurrent archs)
            bucket = ("exact", int(batch["tokens"].shape[0]),
                      int(batch["tokens"].shape[1]))
        return self._call(ROLE_PREFILL, (level, bucket), params, batch)

    def scatter(self, pool, src, slots):
        return self._call(ROLE_SCATTER, (int(src["pos"].shape[0]),),
                          pool, src, slots)

    def purge(self, pool, rows):
        return self._call(ROLE_PURGE, (), pool, rows)

    def decode_paged(self, params, cache, tokens, table, *, level: int = 0):
        return self._call(ROLE_DECODE_PAGED, (level,),
                          params, cache, tokens, table)

    def prefill_ext(self, params, batch, arena, table, *, level: int = 0,
                    bucket=None):
        if bucket is None:
            bucket = ("exact", int(batch["tokens"].shape[0]),
                      int(batch["tokens"].shape[1]))
        return self._call(ROLE_PREFILL_EXT, (level, bucket),
                          params, batch, arena, table)

    def scatter_paged(self, pool, src, slots, table, starts):
        src_s = int(jax.tree.leaves(src["runs"])[0].shape[2])
        return self._call(ROLE_SCATTER_PAGED,
                          (int(src["pos"].shape[0]), src_s),
                          pool, src, slots, table, starts)

    def purge_paged(self, pool, rows, blocks):
        return self._call(ROLE_PURGE_PAGED, (), pool, rows, blocks)

    def copy_blocks(self, pool, src, dst):
        return self._call(ROLE_COPY_BLOCKS, (), pool, src, dst)

    # ---- boot-time precompilation ---------------------------------------
    def _cache_aval(self):
        cfg, scfg = self.cfg, self.scfg
        return jax.eval_shape(
            lambda: self._T.init_cache(cfg, scfg.batch, scfg.max_len))

    def prefill_buckets(self) -> List[int]:
        """The pow2 prompt buckets the engine can ever ask for:
        2, 4, … capped at ``max_len`` (which is itself a bucket when not
        a power of two)."""
        out, b = [], 2
        while b < self.scfg.max_len:
            out.append(b)
            b *= 2
        out.append(self.scfg.max_len)
        return sorted(set(out))

    def _ensure(self, role: str, variant: Tuple, args: Tuple) -> None:
        """Warm-path resolve: guarantee this entry will never need a
        compile at dispatch time, as cheaply as possible. A disk-cached
        entry is left ON DISK — deserialization (~0.1s/entry on the
        bigger models) is deferred to first dispatch, so a warm boot's
        time-to-first-token pays only for the executables the first
        request actually touches. Anything missing compiles (and
        persists) now, which is the whole cold-boot cost."""
        if (role, variant) in self._mem:
            return
        key = cache_key(self.fingerprint, role, variant, _sig_of(args),
                        self.scfg, self.cfg)
        if self.cache.has(key):
            return                 # servable; lazy-deserialized on use
        self._resolve(role, variant, args)

    def warm(self, ladder: Sequence, bucketed: bool,
             paged: bool = False) -> None:
        """Precompile (or cache-verify) the full serving surface: the
        decode step for every elastic-rank rung, every pow2 prefill
        bucket at full rank, and the scatter/purge cache helpers.
        Lowering happens against abstract avals — no model math runs.
        After this returns, steady-state serving performs zero XLA
        compiles (``aot_compiles`` stays flat) no matter which bucket,
        rung or helper a request exercises. With ``paged`` the block-
        arena surface is warmed instead of the contiguous decode/scatter
        (the paged engine never dispatches those roles); the non-paged
        warm set is byte-identical to what it always was."""
        with trace.span("aot_warm", rungs=len(ladder), bucketed=bucketed,
                        paged=paged):
            B = self.scfg.batch
            i32 = jnp.int32
            cache_aval = self._cache_aval()
            tok_aval = jax.ShapeDtypeStruct((B, 1), i32)
            slots_aval = jax.ShapeDtypeStruct((B,), i32)
            if not paged:
                for level, params in enumerate(ladder):
                    self._ensure(ROLE_DECODE, (level,),
                                 (params, cache_aval, tok_aval))
            if bucketed:
                src_aval = None
                for sb in self.prefill_buckets():
                    batch_aval = {
                        "tokens": jax.ShapeDtypeStruct((B, sb), i32),
                        "lengths": jax.ShapeDtypeStruct((B,), i32)}
                    self._ensure(ROLE_PREFILL, (0, sb),
                                 (ladder[0], batch_aval))
                    if src_aval is None:
                        fn, _ = self._role_fn(ROLE_PREFILL)
                        _, src_aval = jax.eval_shape(fn, ladder[0],
                                                     batch_aval)
                if not paged and src_aval is not None:
                    self._ensure(ROLE_SCATTER, (B,),
                                 (cache_aval, src_aval, slots_aval))
            if not paged:
                self._ensure(ROLE_PURGE, (),
                             (cache_aval, slots_aval))
                return
            # ---- paged surface ------------------------------------------
            bkv = int(getattr(self.scfg, "kv_block", 0))
            NB = self.scfg.max_len // bkv
            nblk = B * NB + 1
            arena_aval = jax.eval_shape(
                lambda: self._T.init_cache_paged(self.cfg, B, nblk, bkv))
            tbl_aval = jax.ShapeDtypeStruct((B, NB), i32)
            starts_aval = jax.ShapeDtypeStruct((B,), i32)
            for level, params in enumerate(ladder):
                self._ensure(ROLE_DECODE_PAGED, (level,),
                             (params, arena_aval, tok_aval, tbl_aval))
            pre_fn, _ = self._role_fn(ROLE_PREFILL)
            ext_fn, _ = self._role_fn(ROLE_PREFILL_EXT)
            seen_s = set()
            for sb in self.prefill_buckets():
                batch_aval = {
                    "tokens": jax.ShapeDtypeStruct((B, sb), i32),
                    "lengths": jax.ShapeDtypeStruct((B,), i32)}
                ext_aval = dict(batch_aval, starts=starts_aval)
                self._ensure(ROLE_PREFILL_EXT, (0, sb),
                             (ladder[0], ext_aval, arena_aval, tbl_aval))
                # scatter variants: plain prefill emits max_len-wide src
                # caches, prefill_ext emits bucket-wide ones
                for fn, aval in ((pre_fn, batch_aval), (ext_fn, None)):
                    if aval is not None:
                        _, sa = jax.eval_shape(fn, ladder[0], aval)
                    else:
                        _, sa = jax.eval_shape(fn, ladder[0], ext_aval,
                                               arena_aval, tbl_aval)
                    ss = int(jax.tree.leaves(sa["runs"])[0].shape[2])
                    if ss not in seen_s:
                        seen_s.add(ss)
                        self._ensure(ROLE_SCATTER_PAGED, (B, ss),
                                     (arena_aval, sa, slots_aval,
                                      tbl_aval, starts_aval))
            self._ensure(ROLE_PURGE_PAGED, (),
                         (arena_aval, slots_aval,
                          jax.ShapeDtypeStruct((B * NB,), i32)))
            self._ensure(ROLE_COPY_BLOCKS, (),
                         (arena_aval, slots_aval, slots_aval))
