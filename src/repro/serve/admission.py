"""Admission control for the continuous batcher (DESIGN.md §5).

The batcher's intake used to be an unbounded list: every ``submit``
succeeded, nothing ever aged out, and an operator had no signal before
the process OOMed or latency SLOs silently died. This module makes the
intake an explicit, deterministic policy object:

* **bounded queue with backpressure** — ``offer`` accepts or rejects
  against ``max_queue``; the caller (router, load balancer) sees the
  reject immediately and can spill to another replica.
* **per-request deadlines** — a request carries ``deadline_s`` (relative
  to submit). ``take`` sheds overdue requests *at admission time*, in
  FIFO order, before they waste a prefill: shedding work that already
  missed its SLO is the deterministic policy (no sampling, no load
  heuristics — two identical runs shed identical sets).
* **serve metrics** — one structured dict (queue depth/peak, shed and
  poison counters, TTFT and queue-wait percentiles, rank-bucket
  residency) shared by the engine, the degradation benchmark, the chaos
  tests and ``launch/serve.py --stats-json``, so tests assert on exactly
  the counters operators watch.

Typed request terminal states live here too: a request ends exactly one
of ``done`` / ``shed_queue_full`` / ``shed_deadline`` / ``failed_poison``
(the poisoned path raises/records ``PoisonedRequestError``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

# Terminal request statuses (Request.status)
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
SHED_QUEUE_FULL = "shed_queue_full"
SHED_DEADLINE = "shed_deadline"
FAILED_POISON = "failed_poison"


class PoisonedRequestError(RuntimeError):
    """A request kept producing non-finite logits after exhausting its
    quarantine retry budget (persistent content poison or a persistently
    faulty engine)."""


@dataclass(frozen=True)
class AdmissionConfig:
    max_queue: int = 0           # queued-request bound; 0 = unbounded
    default_deadline_s: Optional[float] = None  # applied when a request
    #                              carries no deadline of its own
    max_retries: int = 2         # poison-quarantine re-queue budget
    # --- elastic-rank degradation ladder ---------------------------------
    elastic: bool = False        # enable serve-time rank degradation
    elastic_levels: int = 2      # degraded pow2 buckets below full rank
    degrade_above: int = 4       # queue depth that drops one rank level
    restore_below: int = 1       # queue depth that restores one level


class ServeMetrics:
    """Counters + latency samples behind ``ContinuousBatcher.metrics()``."""

    def __init__(self) -> None:
        self.counters: Dict[str, int] = {
            "submitted": 0, "accepted": 0, "completed": 0,
            "shed_queue_full": 0, "shed_deadline": 0,
            "poison_events": 0, "poison_retries": 0, "poison_failures": 0,
            "slot_purges": 0, "steps": 0, "peak_queue_depth": 0,
        }
        self.ttft_s: List[float] = []        # submit -> first token
        self.queue_wait_s: List[float] = []  # submit -> admission
        self.rank_residency: Dict[int, int] = {}   # level -> steps spent

    def bump(self, key: str, n: int = 1) -> None:
        self.counters[key] = self.counters.get(key, 0) + n

    def observe_queue_depth(self, depth: int) -> None:
        if depth > self.counters["peak_queue_depth"]:
            self.counters["peak_queue_depth"] = depth

    def step_at_level(self, level: int) -> None:
        self.counters["steps"] += 1
        self.rank_residency[level] = self.rank_residency.get(level, 0) + 1

    @staticmethod
    def _pcts(samples: List[float]) -> Dict[str, float]:
        if not samples:
            return {"p50_ms": 0.0, "p95_ms": 0.0, "mean_ms": 0.0, "n": 0}
        a = np.asarray(samples) * 1e3
        return {"p50_ms": round(float(np.percentile(a, 50)), 3),
                "p95_ms": round(float(np.percentile(a, 95)), 3),
                "mean_ms": round(float(a.mean()), 3), "n": len(a)}

    def snapshot(self, queue_depth: int, rank_level: int,
                 engine_stats: Optional[Dict[str, int]] = None) -> Dict:
        """The serve-metrics dict: everything an operator would watch.
        ``engine_stats`` folds in the batcher's jit-retrace counters."""
        out: Dict = dict(self.counters)
        out["queue_depth"] = queue_depth
        out["rank_level"] = rank_level
        out["rank_residency"] = {str(k): v for k, v in
                                 sorted(self.rank_residency.items())}
        out["ttft"] = self._pcts(self.ttft_s)
        out["queue_wait"] = self._pcts(self.queue_wait_s)
        if engine_stats:
            out["engine"] = dict(engine_stats)
        return out


class AdmissionController:
    """Owns the wait queue; all accept/shed decisions happen here.

    Determinism contract: decisions depend only on (submission order,
    queue bound, request deadlines, the ``now`` values the engine passes
    in). Two runs that submit the same requests in the same order against
    the same config shed/reject the same rids — asserted by the chaos
    suite.
    """

    def __init__(self, cfg: AdmissionConfig, metrics: ServeMetrics):
        self.cfg = cfg
        self.metrics = metrics
        self.queue: List = []          # waiting Requests, FIFO
        self.rejected: List = []       # shed at submit (queue full)
        self.shed: List = []           # shed while queued (deadline)

    def depth(self) -> int:
        return len(self.queue)

    def offer(self, req, now: float) -> bool:
        """Admit ``req`` to the wait queue or reject it (backpressure).
        Returns True iff accepted; a reject marks the request
        ``shed_queue_full`` and keeps it in ``rejected``."""
        self.metrics.bump("submitted")
        req.t_submit = now
        if req.deadline_s is None:
            req.deadline_s = self.cfg.default_deadline_s
        if self.cfg.max_queue and len(self.queue) >= self.cfg.max_queue:
            req.status = SHED_QUEUE_FULL
            self.metrics.bump("shed_queue_full")
            self.rejected.append(req)
            return False
        req.status = QUEUED
        self.metrics.bump("accepted")
        self.queue.append(req)
        self.metrics.observe_queue_depth(len(self.queue))
        return True

    def requeue(self, req) -> None:
        """Put a quarantined request back at the head of the queue (it
        already waited its turn; retrying behind the backlog would let
        one transient fault double a request's latency)."""
        req.status = QUEUED
        self.queue.insert(0, req)
        self.metrics.observe_queue_depth(len(self.queue))

    def take(self, n: int, now: float) -> Tuple[List, List]:
        """Dequeue up to ``n`` admissible requests; shed overdue ones.

        Walks the queue in FIFO order: a request whose deadline has
        already passed while waiting is shed (``shed_deadline``) — it can
        no longer meet its SLO, and prefilling it would only push the
        requests behind it over theirs. Returns (admitted, shed)."""
        admitted: List = []
        shed: List = []
        keep: List = []
        for req in self.queue:
            overdue = (req.deadline_s is not None
                       and now - req.t_submit > req.deadline_s)
            if overdue:
                req.status = SHED_DEADLINE
                shed.append(req)
            elif len(admitted) < n:
                req.status = RUNNING
                req.t_admit = now
                self.metrics.queue_wait_s.append(now - req.t_submit)
                admitted.append(req)
            else:
                keep.append(req)
        self.queue[:] = keep
        if shed:
            self.metrics.bump("shed_deadline", len(shed))
            self.shed.extend(shed)
        return admitted, shed
