"""Admission control for the continuous batcher (DESIGN.md §5).

The batcher's intake used to be an unbounded list: every ``submit``
succeeded, nothing ever aged out, and an operator had no signal before
the process OOMed or latency SLOs silently died. This module makes the
intake an explicit, deterministic policy object:

* **bounded queue with backpressure** — ``offer`` accepts or rejects
  against ``max_queue``; the caller (router, load balancer) sees the
  reject immediately and can spill to another replica.
* **per-request deadlines** — a request carries ``deadline_s`` (relative
  to submit). ``take`` sheds overdue requests *at admission time*, in
  FIFO order, before they waste a prefill: shedding work that already
  missed its SLO is the deterministic policy (no sampling, no load
  heuristics — two identical runs shed identical sets).
* **serve metrics** — one structured snapshot (queue depth/peak, shed
  and poison counters, TTFT and queue-wait percentiles, rank-bucket
  residency) shared by the engine, the degradation benchmark, the chaos
  tests and ``launch/serve.py --stats-json``, so tests assert on exactly
  the counters operators watch. Since the observability PR the samples
  behind the percentiles live in **bounded reservoirs**
  (``obs.metrics.Histogram`` — the old per-request ``ttft_s`` lists grew
  one float per request forever) and the snapshot is the versioned
  ``repro.serve.metrics/v2`` schema, with every pre-v2 top-level key
  kept as a deprecated alias for one release.

Typed request terminal states live here too: a request ends exactly one
of ``done`` / ``shed_queue_full`` / ``shed_deadline`` / ``failed_poison``
(the poisoned path raises/records ``PoisonedRequestError``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import Histogram, MetricsRegistry

# Terminal request statuses (Request.status)
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
SHED_QUEUE_FULL = "shed_queue_full"
SHED_DEADLINE = "shed_deadline"
SHED_OVERLONG = "shed_overlong"
FAILED_POISON = "failed_poison"


class PoisonedRequestError(RuntimeError):
    """A request kept producing non-finite logits after exhausting its
    quarantine retry budget (persistent content poison or a persistently
    faulty engine)."""


@dataclass(frozen=True)
class AdmissionConfig:
    max_queue: int = 0           # queued-request bound; 0 = unbounded
    default_deadline_s: Optional[float] = None  # applied when a request
    #                              carries no deadline of its own
    max_retries: int = 2         # poison-quarantine re-queue budget
    reject_overlong: bool = False  # shed prompts > max_len - 1 instead of
    #                              silently truncating to the newest tokens
    # --- elastic-rank degradation ladder ---------------------------------
    elastic: bool = False        # enable serve-time rank degradation
    elastic_levels: int = 2      # degraded pow2 buckets below full rank
    degrade_above: int = 4       # queue depth that drops one rank level
    restore_below: int = 1       # queue depth that restores one level


class ServeMetrics:
    """Counters + latency reservoirs behind ``ContinuousBatcher.metrics()``.

    Backed by an ``obs.metrics.MetricsRegistry``: counters are typed,
    latency samples go into bounded reservoirs (fixed memory no matter
    how many requests pass through — the pre-v2 ``ttft_s``/
    ``queue_wait_s`` lists grew unboundedly), and ``snapshot()`` emits
    the versioned v2 schema with the legacy keys preserved as a
    deprecated alias for one release.
    """

    COUNTER_KEYS = ("submitted", "accepted", "completed",
                    "shed_queue_full", "shed_deadline", "shed_overlong",
                    "poison_events", "poison_retries", "poison_failures",
                    "slot_purges", "steps", "prompt_truncations",
                    "prefix_hits", "prefix_misses", "prefix_evictions",
                    "cow_forks")

    def __init__(self, registry: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry or MetricsRegistry()
        for k in self.COUNTER_KEYS:
            self.registry.counter(k)
        self.registry.gauge("queue_depth")
        self.registry.gauge("peak_queue_depth")
        self.registry.gauge("rank_level")
        self._ttft = self.registry.histogram("ttft_ms")
        self._queue_wait = self.registry.histogram("queue_wait_ms")
        self._step = self.registry.histogram("step_ms")
        self.rank_residency: Dict[int, int] = {}   # level -> steps spent

    def bump(self, key: str, n: int = 1) -> None:
        self.registry.counter(key).inc(n)

    def count(self, key: str) -> int:
        return self.registry.counter(key).value

    @property
    def counters(self) -> Dict[str, int]:
        """Legacy read surface (pre-v2 callers indexed a plain dict)."""
        out = {k: c.value for k, c in self.registry.counters.items()}
        out["peak_queue_depth"] = int(
            self.registry.gauges["peak_queue_depth"].value)
        return out

    def observe_queue_depth(self, depth: int) -> None:
        self.registry.gauge("queue_depth").set(depth)
        self.registry.gauge("peak_queue_depth").set_max(depth)

    def observe_ttft(self, seconds: float) -> None:
        self._ttft.observe(seconds * 1e3)

    def observe_queue_wait(self, seconds: float) -> None:
        self._queue_wait.observe(seconds * 1e3)

    def observe_step_ms(self, ms: float) -> None:
        self._step.observe(ms)

    def step_at_level(self, level: int) -> None:
        self.registry.counter("steps").inc()
        self.registry.gauge("rank_level").set(level)
        self.rank_residency[level] = self.rank_residency.get(level, 0) + 1

    @staticmethod
    def _pcts(hist: Histogram) -> Dict[str, float]:
        """Legacy ``{p50_ms, p95_ms, mean_ms, n}`` block from a
        millisecond reservoir. Exact on 0 samples (all-zero with
        ``n == 0``, so "no data" is distinguishable from a measured
        0 ms) and on 1 sample (that sample at every percentile)."""
        s = hist.summary()
        return {"p50_ms": s["p50"], "p95_ms": s["p95"],
                "mean_ms": s["mean"], "n": s["n"]}

    def snapshot(self, queue_depth: int, rank_level: int,
                 engine_stats: Optional[Dict[str, int]] = None) -> Dict:
        """The serve-metrics snapshot: everything an operator would
        watch, as the versioned ``repro.serve.metrics/v2`` schema
        (``schema`` / ``counters`` / ``gauges`` / ``histograms`` /
        ``rank_residency``). ``engine_stats`` folds the batcher's
        jit-retrace and AOT counters into the same ``counters`` block —
        one surface for all three historical stats shapes.

        Every pre-v2 top-level key (``submitted``, ``ttft`` with
        ``*_ms`` percentiles, ``engine``, ...) is still present as a
        **deprecated alias** for one release; consumers should move to
        the typed blocks."""
        self.registry.gauge("queue_depth").set(queue_depth)
        self.registry.gauge("rank_level").set(rank_level)
        residency = {str(k): v for k, v in
                     sorted(self.rank_residency.items())}
        out = self.registry.snapshot(
            extra={"rank_residency": residency})
        if engine_stats:
            out["counters"].update(engine_stats)
        # ---- deprecated legacy aliases (one release) ----------------------
        out.update(self.counters)
        out["queue_depth"] = queue_depth
        out["rank_level"] = rank_level
        out["ttft"] = self._pcts(self._ttft)
        out["queue_wait"] = self._pcts(self._queue_wait)
        if engine_stats:
            out["engine"] = dict(engine_stats)
        return out


class AdmissionController:
    """Owns the wait queue; all accept/shed decisions happen here.

    Determinism contract: decisions depend only on (submission order,
    queue bound, request deadlines, the ``now`` values the engine passes
    in). Two runs that submit the same requests in the same order against
    the same config shed/reject the same rids — asserted by the chaos
    suite.
    """

    def __init__(self, cfg: AdmissionConfig, metrics: ServeMetrics):
        self.cfg = cfg
        self.metrics = metrics
        self.queue: List = []          # waiting Requests, FIFO
        self.rejected: List = []       # shed at submit (queue full)
        self.shed: List = []           # shed while queued (deadline)

    def depth(self) -> int:
        return len(self.queue)

    def offer(self, req, now: float) -> bool:
        """Admit ``req`` to the wait queue or reject it (backpressure).
        Returns True iff accepted; a reject marks the request
        ``shed_queue_full`` and keeps it in ``rejected``."""
        self.metrics.bump("submitted")
        req.t_submit = now
        if req.deadline_s is None:
            req.deadline_s = self.cfg.default_deadline_s
        if self.cfg.max_queue and len(self.queue) >= self.cfg.max_queue:
            req.status = SHED_QUEUE_FULL
            self.metrics.bump("shed_queue_full")
            self.rejected.append(req)
            return False
        req.status = QUEUED
        self.metrics.bump("accepted")
        self.queue.append(req)
        self.metrics.observe_queue_depth(len(self.queue))
        return True

    def requeue(self, req) -> None:
        """Put a quarantined request back at the head of the queue (it
        already waited its turn; retrying behind the backlog would let
        one transient fault double a request's latency)."""
        req.status = QUEUED
        self.queue.insert(0, req)
        self.metrics.observe_queue_depth(len(self.queue))

    def take(self, n: int, now: float) -> Tuple[List, List]:
        """Dequeue up to ``n`` admissible requests; shed overdue ones.

        Walks the queue in FIFO order: a request whose deadline has
        already passed while waiting is shed (``shed_deadline``) — it can
        no longer meet its SLO, and prefilling it would only push the
        requests behind it over theirs. Returns (admitted, shed)."""
        admitted: List = []
        shed: List = []
        keep: List = []
        for req in self.queue:
            overdue = (req.deadline_s is not None
                       and now - req.t_submit > req.deadline_s)
            if overdue:
                req.status = SHED_DEADLINE
                shed.append(req)
            elif len(admitted) < n:
                req.status = RUNNING
                req.t_admit = now
                self.metrics.observe_queue_wait(now - req.t_submit)
                admitted.append(req)
            else:
                keep.append(req)
        self.queue[:] = keep
        if shed:
            self.metrics.bump("shed_deadline", len(shed))
            self.shed.extend(shed)
        return admitted, shed
