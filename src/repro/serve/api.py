"""Typed public serving API (DESIGN.md §5.6).

This module is the stable, importable surface over the serving stack:
:class:`ServeOptions` (a frozen dataclass holding every knob the CLI
exposes), :func:`load_engine` (options → a ready engine), and
:func:`serve` (options → a drained workload with a structured report).
``repro.launch.serve`` is a thin argparse shim over these — anything a
flag can do, the dataclass can do from Python, and validation lives
here (once) instead of in parser callbacks.

    from repro.serve.api import ServeOptions, serve
    res = serve(ServeOptions(arch="llama-mini",
                             compressed_ckpt="runs/mini_drank30",
                             aot=True, requests=16, n_new=32))
    assert res.status == "drained"
    print(res.report["tokens_per_s"])

The AOT boot path (``aot=True``) swaps the engine's lazily traced
executables for an :class:`~repro.serve.aot.AotRegistry` keyed on the
artifact fingerprint: a warm persistent cache makes boot-to-first-token
O(deserialize) instead of O(compile) — see ``serve/aot.py``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs import flightrec as frec
from repro.obs import trace
from repro.obs.metrics import MetricsExporter, MetricsServer
from repro.serve import admission as adm
from repro.serve import aot as aotlib
from repro.serve.aot import AotCache, AotRegistry, TracedRegistry
from repro.serve.engine import (ContinuousBatcher, DrainResult, Engine,
                                Request, ServeConfig, from_compressed)
from repro.serve.frontdoor import FrontDoor, Router, TokenStream

__all__ = [
    "ServeOptions", "load_engine", "serve",
    "from_compressed", "Engine", "ContinuousBatcher",
    "Request", "DrainResult", "ServeConfig",
    "FrontDoor", "Router", "TokenStream",
    "AotRegistry", "TracedRegistry", "AotCache",
]

_CALIB_BATCH = 8          # rows per calibration batch (matches launch CLI)


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Everything the serving stack can be asked to do, as one frozen
    value. Field names are the CLI flags with ``-`` → ``_`` (the one
    rename: ``--slots`` is the deprecated alias of ``batch``).
    Cross-field validation runs at construction — a bad combination
    fails here, not minutes later inside a jit trace.

    >>> opts = ServeOptions(arch="llama-mini", n_new=8)
    >>> (opts.batch, opts.aot, opts.replicas)
    (4, False, 1)
    >>> ServeOptions(arch="llama-mini", compress="nope")
    Traceback (most recent call last):
        ...
    ValueError: unknown compression method 'nope'
    >>> ServeOptions(arch="llama-mini", whiten_stream=True,
    ...              eager_capture=True)
    Traceback (most recent call last):
        ...
    ValueError: whiten_stream needs the streaming capture; drop eager_capture
    """

    arch: str
    # --- model / artifact sources ----------------------------------------
    ckpt: str = ""
    compress: str = ""              # one of core.compress.METHODS, or ""
    ratio: float = 0.3
    group_size: int = 2
    beta: float = 0.3
    compressed_ckpt: str = ""       # boot from a save_plan artifact
    save_compressed: str = ""       # after compress, persist here
    verify: bool = False            # re-hash artifact against manifest
    load_retries: int = 0           # transient-load retry budget
    # --- calibration (only with compress=) -------------------------------
    eager_capture: bool = False
    whiten_stream: bool = False
    calib_mesh_shards: int = 0
    shard_grams_above: int = 4096
    calib_samples: int = 16
    calib_seq: int = 128
    device_compress: bool = False
    rsvd_threshold: int = 0
    # --- engine shape -----------------------------------------------------
    batch: int = 4                  # decode slots (CLI: --batch / --slots)
    max_len: int = 256
    kv_block: int = 0               # paged KV block size; 0 = contiguous
    prefix_cache: bool = False      # share prompt-prefix blocks (paged)
    # --- synthetic workload (serve()) -------------------------------------
    requests: int = 8
    prompt_len: int = 16
    n_new: int = 32
    seed: int = 0
    # --- resilience (DESIGN.md §5) ----------------------------------------
    max_queue: int = 0
    deadline_s: Optional[float] = None
    max_retries: int = 2
    reject_overlong: bool = False   # shed over-long prompts typed instead
    #                                 of truncating to the newest tokens
    elastic: bool = False
    elastic_levels: int = 2
    watchdog_s: Optional[float] = None
    heartbeat_dir: str = ""
    fault_plan: str = ""
    stats_json: str = ""
    # --- front door -------------------------------------------------------
    aot: bool = False               # AOT-compiled executables + disk cache
    aot_cache_dir: str = ""         # "" = $REPRO_AOT_CACHE or ~/.cache
    replicas: int = 1               # N engines behind one Router
    stream: bool = False            # drive through FrontDoor even for N=1
    # --- observability (DESIGN.md §6) -------------------------------------
    trace_out: str = ""             # Chrome-trace JSON path (Perfetto)
    device_trace_dir: str = ""      # jax.profiler logdir (device timeline)
    metrics_json: str = ""          # periodic v2 metrics snapshot JSON
    metrics_interval_s: float = 1.0  # exporter cadence for metrics_json
    metrics_port: int = -1          # Prometheus /metrics; -1 off, 0 ephemeral
    flightrec_dir: str = ""         # flight-recorder dump directory

    def __post_init__(self):
        from repro.core.compress import METHODS
        if self.compress and self.compress not in METHODS:
            raise ValueError(
                f"unknown compression method '{self.compress}'")
        if self.compress and self.compressed_ckpt:
            raise ValueError(
                "compress= and compressed_ckpt= conflict: an artifact "
                "is already compressed")
        if self.save_compressed and not self.compress:
            raise ValueError("save_compressed= needs compress=")
        if self.whiten_stream and self.eager_capture:
            raise ValueError("whiten_stream needs the streaming capture; "
                             "drop eager_capture")
        if self.calib_mesh_shards > 1:
            if self.eager_capture:
                raise ValueError("calib_mesh_shards needs the streaming "
                                 "capture; drop eager_capture")
            if _CALIB_BATCH % self.calib_mesh_shards != 0:
                raise ValueError(
                    f"calib_mesh_shards {self.calib_mesh_shards} must "
                    f"divide the calibration batch of {_CALIB_BATCH} rows")
            if self.calib_samples % _CALIB_BATCH != 0:
                raise ValueError(
                    f"calib_samples {self.calib_samples} must be a "
                    f"multiple of {_CALIB_BATCH} with calib_mesh_shards "
                    f"(a ragged final batch cannot split over the mesh)")
        if self.batch < 1 or self.max_len < 1:
            raise ValueError("batch and max_len must be >= 1")
        if self.kv_block < 0:
            raise ValueError("kv_block must be >= 0 (0 = contiguous)")
        if self.kv_block:
            if self.kv_block % 8:
                raise ValueError("kv_block must be a multiple of 8 "
                                 "(TPU sublane alignment)")
            if self.max_len % self.kv_block:
                raise ValueError(
                    f"kv_block {self.kv_block} must divide max_len "
                    f"{self.max_len}")
        if self.prefix_cache and not self.kv_block:
            raise ValueError("prefix_cache requires kv_block > 0")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if not -1 <= self.metrics_port <= 65535:
            raise ValueError("metrics_port must be -1 (off), 0 "
                             "(ephemeral) or a valid TCP port")
        if self.metrics_interval_s <= 0:
            raise ValueError("metrics_interval_s must be > 0")

    def serve_config(self) -> ServeConfig:
        return ServeConfig(batch=self.batch, max_len=self.max_len,
                           kv_block=self.kv_block,
                           prefix_cache=self.prefix_cache)

    def admission_config(self) -> "adm.AdmissionConfig":
        return adm.AdmissionConfig(max_queue=self.max_queue,
                                   default_deadline_s=self.deadline_s,
                                   max_retries=self.max_retries,
                                   reject_overlong=self.reject_overlong,
                                   elastic=self.elastic,
                                   elastic_levels=self.elastic_levels)


def _echo(echo: Optional[Callable[[str], None]], msg: str) -> None:
    if echo is not None:
        echo(msg)


def _resilience_kwargs(opts: ServeOptions, replica: int = 0,
                       echo=None) -> Dict:
    faults = None
    if opts.fault_plan:
        from repro.dist.faultinject import FaultPlan
        faults = FaultPlan.from_json(opts.fault_plan)
        _echo(echo, f"fault plan armed: {faults.to_json()}")
    heartbeat = None
    if opts.heartbeat_dir:
        from repro.dist.ft import Heartbeat
        heartbeat = Heartbeat(os.path.join(opts.heartbeat_dir,
                                           f"worker{replica}.json"),
                              fault=faults)
    flight = frec.FlightRecorder(dump_dir=opts.flightrec_dir or None)
    return dict(admission=opts.admission_config(), faults=faults,
                heartbeat=heartbeat, flight=flight)


def _compress_in_process(opts: ServeOptions, params, cfg, echo=None):
    """The compress-at-boot path: calibrate on synthetic data, build the
    plan, optionally persist the artifact. Returns (params, plan)."""
    import jax
    import jax.numpy as jnp

    from repro.core import compress as CC
    from repro.data.synthetic import DataConfig, calibration_batches

    mesh = None
    if opts.calib_mesh_shards > 1:
        n_dev = len(jax.devices())
        if n_dev < opts.calib_mesh_shards:
            raise ValueError(
                f"calib_mesh_shards={opts.calib_mesh_shards} but only "
                f"{n_dev} local devices (set XLA_FLAGS=--xla_force_host_"
                f"platform_device_count={opts.calib_mesh_shards} to fake "
                f"a host mesh)")
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(data=opts.calib_mesh_shards, model=1)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=opts.calib_seq,
                      global_batch=_CALIB_BATCH)
    calib = [{"tokens": jnp.asarray(b["tokens"])}
             for b in calibration_batches(dcfg, opts.calib_samples,
                                          _CALIB_BATCH)]
    ccfg = CC.CompressionConfig(method=opts.compress, ratio=opts.ratio,
                                group_size=opts.group_size, beta=opts.beta,
                                rsvd_threshold=opts.rsvd_threshold)
    params, plan = CC.build_plan_and_params(
        params, cfg, ccfg, calib,
        streaming=not opts.eager_capture,
        device=opts.device_compress,
        mesh=mesh,
        whiten_tags=(True if opts.whiten_stream else None),
        shard_grams_above=opts.shard_grams_above)
    _echo(echo, f"compressed with {opts.compress}: "
                f"{plan.summary['achieved_ratio']:.1%} removed")
    if opts.save_compressed:
        path = CC.save_plan(opts.save_compressed, params, plan, cfg)
        _echo(echo, f"saved compressed artifact to {path}")
    return params, plan


def _registry_for(opts: ServeOptions, cfg, scfg, fingerprint: str):
    if not opts.aot:
        return None                       # engine defaults to traced
    return AotRegistry(cfg, scfg, fingerprint,
                       cache_dir=opts.aot_cache_dir or None)


def load_engine(opts: ServeOptions, *, replica: int = 0,
                echo: Optional[Callable[[str], None]] = None
                ) -> ContinuousBatcher:
    """Options → a ready :class:`ContinuousBatcher`.

    Resolves the model source (compressed artifact > checkpoint > random
    init), runs compress-at-boot if asked, wires the resilience layer,
    and — with ``aot=True`` — attaches an :class:`AotRegistry` keyed on
    the artifact fingerprint and warms the whole serving surface, so the
    returned engine never traces during steady state. ``echo`` receives
    human-readable boot progress lines (the CLI passes ``print``)."""
    from repro.configs import get_config

    cfg = get_config(opts.arch)
    scfg = opts.serve_config()
    resil = _resilience_kwargs(opts, replica=replica, echo=echo)

    if opts.compressed_ckpt:
        from repro.ckpt.store import artifact_fingerprint
        from repro.core.compress import ARTIFACT_NAME
        fp = artifact_fingerprint(opts.compressed_ckpt, name=ARTIFACT_NAME)
        reg = _registry_for(opts, cfg, scfg, fp)
        cb = from_compressed(opts.compressed_ckpt, cfg, scfg,
                             verify=opts.verify,
                             load_retries=opts.load_retries,
                             executables=reg, **resil)
        _echo(echo, f"booted from compressed checkpoint "
                    f"{opts.compressed_ckpt} "
                    f"({cb.plan.summary['achieved_ratio']:.1%} removed, "
                    f"method={cb.plan.config.method}"
                    + (", integrity verified" if opts.verify else "") + ")")
    else:
        import jax

        from repro.models import transformer as T
        if opts.ckpt:
            from repro.ckpt import store
            from repro.train import step as TS
            state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0))
            step, state = store.restore(opts.ckpt, state)
            params = state.params
            _echo(echo, f"loaded {opts.ckpt} @ step {step}")
        else:
            params, _ = T.init_model(cfg, jax.random.PRNGKey(opts.seed))
            _echo(echo, "serving a randomly initialized model (no ckpt)")
        plan = None
        if opts.compress:
            params, plan = _compress_in_process(opts, params, cfg,
                                                echo=echo)
        reg = _registry_for(opts, cfg, scfg,
                            aotlib.live_fingerprint(params, cfg))
        cb = ContinuousBatcher(params, cfg, scfg, executables=reg, **resil)
        cb.plan = plan
    if opts.aot:
        t0 = time.perf_counter()
        cb.warm_executables()
        s = cb.stats
        _echo(echo, f"AOT warm in {time.perf_counter() - t0:.2f}s: "
                    f"{s['aot_cache_hits']} cache hits, "
                    f"{s['aot_compiles']} compiles "
                    f"(cache: {cb.exec.cache.dir})")
    return cb


def _workload(opts: ServeOptions, vocab_size: int) -> List[Request]:
    rng = np.random.default_rng(opts.seed)
    return [Request(rid=i, n_new=opts.n_new,
                    tokens=rng.integers(0, vocab_size,
                                        size=(opts.prompt_len,),
                                        dtype=np.int32))
            for i in range(opts.requests)]


def _report(result: DrainResult, stats, accepted: int, requests: int,
            dt: float) -> Dict:
    toks = sum(len(r.out) for r in result)
    lat = [r.t_done - r.t_submit for r in result]
    return {
        "drain_status": result.status,   # drained | timeout | stalled
        "requests": len(result),
        "accepted": accepted,
        "submitted": requests,
        "shed": len(result.shed),
        "rejected": len(result.rejected),
        "failed": len(result.failed),
        "generated_tokens": toks,
        "tokens_per_s": round(toks / dt, 1) if toks else 0.0,
        "mean_latency_s": round(float(np.mean(lat)), 3) if lat else 0.0,
        "p95_latency_s": (round(float(np.percentile(lat, 95)), 3)
                          if lat else 0.0),
        "engine_stats": stats,           # retrace/AOT counters, admissions
    }


def serve(opts: ServeOptions, *,
          echo: Optional[Callable[[str], None]] = None) -> DrainResult:
    """Run the synthetic workload described by ``opts`` to drain and
    return the :class:`DrainResult`, with the structured report attached
    as ``result.report``.

    ``replicas == 1`` and ``stream=False`` drives the engine directly
    (``run_until_drained``, byte-identical to the historical CLI path);
    ``replicas > 1`` or ``stream=True`` goes through the front door — N
    engines behind a :class:`Router` that places each request on the
    least-loaded replica and spills on backpressure.

    Observability (DESIGN.md §6): ``trace_out`` records the whole run as
    Chrome-trace JSON (load it in https://ui.perfetto.dev);
    ``device_trace_dir`` adds a ``jax.profiler`` device capture;
    ``metrics_json``/``metrics_port`` export the live v2 metrics
    snapshot as periodic JSON / a Prometheus scrape endpoint;
    ``flightrec_dir`` arms per-engine flight-recorder dumps."""
    if opts.trace_out or opts.device_trace_dir:
        with trace.tracing(out=opts.trace_out or None):
            with trace.device_trace(opts.device_trace_dir or None):
                result = _serve_inner(opts, echo=echo)
        if opts.trace_out:
            _echo(echo, f"trace written to {opts.trace_out} "
                        f"(load in https://ui.perfetto.dev)")
        return result
    return _serve_inner(opts, echo=echo)


def _serve_inner(opts: ServeOptions, *,
                 echo: Optional[Callable[[str], None]] = None
                 ) -> DrainResult:
    from repro.configs import get_config

    cfg = get_config(opts.arch)
    t0 = time.perf_counter()
    engines = [load_engine(opts, replica=i,
                           echo=echo if i == 0 else None)
               for i in range(opts.replicas)]
    reqs = _workload(opts, cfg.vocab_size)

    multi = opts.replicas > 1 or opts.stream
    exporter = server = None
    if opts.metrics_json:
        supplier = ((lambda: [e.metrics() for e in engines]) if multi
                    else engines[0].metrics)
        exporter = MetricsExporter(opts.metrics_json, supplier,
                                   interval_s=opts.metrics_interval_s
                                   ).start()
    if opts.metrics_port >= 0:
        server = MetricsServer(lambda: [e.metrics() for e in engines],
                               port=opts.metrics_port).start()
        _echo(echo, f"metrics: http://127.0.0.1:{server.port}/metrics")
    try:
        if multi:
            router = Router([FrontDoor(e) for e in engines]).start()
            accepted = 0
            for r in reqs:
                st = router.submit(r.tokens, r.n_new,
                                   deadline_s=opts.deadline_s, rid=r.rid)
                accepted += st is not None
            result = router.drain_all(timeout=opts.watchdog_s)
            router.close()
            stats = [e.stats for e in engines]
            metrics = [d.metrics() for d in router.doors]
        else:
            cb = engines[0]
            accepted = 0
            for r in reqs:
                accepted += cb.submit(r)
            result = cb.run_until_drained(watchdog_s=opts.watchdog_s)
            stats = cb.stats
            metrics = cb.metrics()
    finally:
        if exporter is not None:
            exporter.stop()
            _echo(echo, f"metrics snapshot written to {opts.metrics_json}")
        if server is not None:
            server.stop()
    if accepted < opts.requests:
        _echo(echo, f"backpressure: {opts.requests - accepted}/"
                    f"{opts.requests} requests rejected at submit "
                    f"(max_queue={opts.max_queue})")
    dumped = [p for e in engines for p in e.flight.dumps]
    if dumped:
        _echo(echo, "flight-recorder artifacts: " + ", ".join(dumped))
    dt = time.perf_counter() - t0
    result.report = _report(result, stats, accepted, opts.requests, dt)
    if opts.stats_json:
        with open(opts.stats_json, "w") as f:
            json.dump(metrics, f, indent=1)
        _echo(echo, f"serve metrics written to {opts.stats_json}")
    return result
