"""Host-side bookkeeping for the paged KV cache (DESIGN.md §5.7).

The device side of paging is dumb on purpose: one flat block arena per
layer run (``transformer.init_cache_paged``), a ``(batch, NB)`` int32
block table uploaded per call, and kernels/scatters that indirect every
read/write through it. Everything stateful lives here, in plain numpy/
Python, where it is deterministic and trivially testable:

* **BlockPool** — the physical allocator: a LIFO free list (block ids
  descending, so two identical runs allocate identical block sequences)
  plus per-block refcounts. Block 0 is the reserved *null block*: never
  allocated, never written (the device write path drops stores whose
  table entry is 0), the sentinel target for dead table entries.
* **PrefixCache** — refcounted immutable prompt-prefix blocks, keyed by
  a per-block chain of (parent entry, block token content). Requests
  sharing a prompt header point their table rows at the same physical
  blocks; admission prefills only the unshared tail. A partial match
  inside one block is a **copy-on-write fork**: the divergence block is
  copied into a fresh block and the tail prefill starts after the
  copied tokens. Entries hold one pool reference each; eviction is LRU
  over leaf entries whose block no request holds.

Sharing is sound because a KV row at position t is a pure function of
tokens[0..t] (causal stack): two prompts identical through t have
bit-identical KV there, so the blocks are immutable and shareable.
Only FULL blocks that no future decode writes into are ever registered:
a prompt of length n contributes its first ``n // bk`` blocks (the
partially-filled block keeps receiving generated tokens and stays
private).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class BlockPool:
    """Refcounted physical-block allocator over ``blocks`` arena slots.

    Deterministic: the free list is a stack initialized ``blocks-1 … 1``
    (block 0 = reserved null block), so allocation order is a pure
    function of the alloc/free history. ``peak_in_use`` backs the
    peak-KV-bytes benchmark claim."""

    def __init__(self, blocks: int):
        assert blocks >= 2, "need at least the null block + one real block"
        self.blocks = blocks
        self.free: List[int] = list(range(1, blocks))   # pop() -> blocks-1
        self.ref = np.zeros((blocks,), dtype=np.int64)
        self.peak_in_use = 0

    @property
    def in_use(self) -> int:
        return self.blocks - 1 - len(self.free)

    def can_alloc(self, n: int) -> bool:
        return len(self.free) >= n

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks at refcount 1, or ``None`` (and no change)
        if the pool can't satisfy the request."""
        if n < 0 or len(self.free) < n:
            return None
        out = [self.free.pop() for _ in range(n)]
        for b in out:
            self.ref[b] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return out

    def incref(self, block: int) -> None:
        assert block != 0 and self.ref[block] > 0, block
        self.ref[block] += 1

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True iff the block was freed."""
        assert block != 0 and self.ref[block] > 0, block
        self.ref[block] -= 1
        if self.ref[block] == 0:
            self.free.append(block)
            return True
        return False


@dataclasses.dataclass
class _Entry:
    eid: int               # unique id; 0 is the implicit root
    block: int             # physical arena block
    tokens: Tuple[int, ...]  # the bk token ids this block holds
    parent: int            # parent entry id (0 = root)
    lru: int               # last-touch clock tick


@dataclasses.dataclass
class PrefixPlan:
    """Admission plan for one request against the prefix cache.

    ``shared`` entries are reused verbatim (the caller increfs their
    blocks into the request's table). ``cow`` is the partial-overlap
    fork: copy ``cow_src`` into a fresh block and start the tail prefill
    ``cow_len`` tokens into it. ``start`` is the first position the tail
    prefill must compute (= len(shared)*bk + cow_len)."""
    shared: List[_Entry]
    cow_src: int = 0       # donor physical block (0 = no fork)
    cow_len: int = 0       # tokens shared inside the divergence block
    start: int = 0


class PrefixCache:
    """LRU-refcounted trie of immutable full prompt-prefix blocks."""

    def __init__(self, bk: int):
        self.bk = bk
        self.entries: Dict[Tuple[int, Tuple[int, ...]], _Entry] = {}
        self.children: Dict[int, List[_Entry]] = {}
        self._next_id = 1
        self._clock = 0

    def __len__(self) -> int:
        return len(self.entries)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def plan(self, tokens: np.ndarray) -> PrefixPlan:
        """Longest reusable prefix of ``tokens`` (prompt, pre-admission).
        Caps sharing at ``len(tokens) - 1`` so at least one tail token
        remains to prefill (the admission logits come from it)."""
        bk = self.bk
        n = len(tokens)
        tick = self._tick()
        shared: List[_Entry] = []
        parent = 0
        nfull = max(0, (n - 1) // bk)      # full blocks, keeping >= 1 tail
        for i in range(nfull):
            blk = tuple(int(t) for t in tokens[i * bk:(i + 1) * bk])
            e = self.entries.get((parent, blk))
            if e is None:
                break
            e.lru = tick
            shared.append(e)
            parent = e.eid
        start = len(shared) * bk
        # copy-on-write fork: the best partial overlap inside the next
        # block (first-max tie break over insertion order)
        cow_src, cow_len = 0, 0
        cap = min(bk - 1, n - 1 - start)   # keep >= 1 tail token
        if cap > 0:
            nxt = [int(t) for t in tokens[start:start + bk]]
            for child in self.children.get(parent, ()):
                d = 0
                for a, b in zip(child.tokens, nxt):
                    if a != b or d >= cap:
                        break
                    d += 1
                if d > cow_len:
                    cow_src, cow_len = child.block, d
        return PrefixPlan(shared=shared, cow_src=cow_src, cow_len=cow_len,
                          start=start + cow_len)

    def register(self, tokens: np.ndarray, table_row: np.ndarray,
                 pool: BlockPool) -> int:
        """After a successful admission: publish the prompt's full blocks
        (``len // bk`` of them — the partial block stays private). Each
        NEW entry takes one extra pool reference (the cache's own hold).
        Returns the number of entries created."""
        bk = self.bk
        created = 0
        parent = 0
        tick = self._tick()
        for i in range(len(tokens) // bk):
            blk = tuple(int(t) for t in tokens[i * bk:(i + 1) * bk])
            e = self.entries.get((parent, blk))
            if e is None:
                e = _Entry(eid=self._next_id, block=int(table_row[i]),
                           tokens=blk, parent=parent, lru=tick)
                self._next_id += 1
                self.entries[(parent, blk)] = e
                self.children.setdefault(parent, []).append(e)
                pool.incref(e.block)
                created += 1
            else:
                e.lru = tick
            parent = e.eid
        return created

    def _remove(self, e: _Entry) -> None:
        del self.entries[(e.parent, e.tokens)]
        sibs = self.children.get(e.parent)
        if sibs is not None:
            sibs.remove(e)
            if not sibs:
                del self.children[e.parent]

    def evict_lru(self, pool: BlockPool) -> bool:
        """Drop the least-recently-used *leaf* entry whose block only the
        cache still holds (refcount 1). Returns True iff one was evicted
        (its block returns to the free list, NOT zeroed — stale KV in a
        freed block is unreachable: no table points at it, and masked
        positions contribute exact zeros)."""
        best: Optional[_Entry] = None
        for e in self.entries.values():
            if e.eid in self.children:     # interior: children pin it
                continue
            if pool.ref[e.block] != 1:     # some request still holds it
                continue
            if best is None or e.lru < best.lru:
                best = e
        if best is None:
            return False
        self._remove(best)
        pool.decref(best.block)
        return True

    def evict_blocks(self, blocks: Sequence[int], pool: BlockPool) -> int:
        """Poison-purge support: drop every cache entry whose physical
        block is in ``blocks`` (deepest-first so parents become leaves),
        releasing the cache's reference. Returns entries evicted."""
        bset = set(int(b) for b in blocks)
        victims = [e for e in self.entries.values() if e.block in bset]
        evicted = 0
        # children reference parents by eid; removing deepest-first keeps
        # the trie consistent (orphaned subtrees of a poisoned block must
        # go too — their chain includes the poisoned content)
        while victims:
            vids = {v.eid for v in victims}
            orphans = [e for e in self.entries.values()
                       if e.parent in vids and e not in victims]
            if not orphans:
                break
            victims.extend(orphans)
        for e in sorted(victims, key=lambda e: -e.eid):
            if (e.parent, e.tokens) in self.entries:
                self._remove(e)
                pool.decref(e.block)
                evicted += 1
        return evicted
