"""Serving front door: async request intake decoupled from the step
loop, per-token streaming output, and a multi-replica router
(DESIGN.md §5.6).

``ContinuousBatcher`` is a synchronous object: callers submit, then
somebody drives ``step()``. The front door turns it into a service:

* **FrontDoor** owns one engine thread that drives the step loop and a
  bounded *intake* queue that any number of client threads write into
  (``submit`` is non-blocking: a full intake queue is immediate
  backpressure, before the admission queue is even consulted). Each
  accepted request gets a :class:`TokenStream` — tokens arrive on it as
  the engine emits them, not when the request completes.
* **Router** fronts N replicas (one ``FrontDoor`` + engine each) behind
  a single ``submit``: requests route to the least-loaded replica using
  the PR 6 signals — intake depth, admission-queue depth, busy slots —
  and spill to the next replica when one pushes back. The routing logic
  only reads those metrics, so the same policy fronts in-process
  replicas here or engine processes behind a socket transport.

Streaming semantics under the resilience layer: a poison-quarantine
requeue *rewinds* a request (its emitted prefix is discarded and
regenerated), so a ``TokenStream`` exposes ``rewinds`` and its
``tokens()`` after completion is authoritative (always equals the
request's final output). Terminal states mirror
``serve.admission``: done / shed_queue_full / shed_deadline /
failed_poison.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ContinuousBatcher, DrainResult, Request

_END = object()          # stream sentinel
_REWIND = object()


class TokenStream:
    """Per-request streaming handle. The engine thread pushes tokens as
    they are emitted; consumers iterate (blocking) or poll.

    >>> # iter(stream) yields ints until the request reaches a terminal
    >>> # state; stream.result(timeout) waits and returns the Request.
    """

    def __init__(self, request: Request):
        self.request = request
        self.rewinds = 0           # poison-quarantine restarts observed
        self._q: "queue.Queue" = queue.Queue()
        self._terminal = threading.Event()

    # ---- engine-thread side ---------------------------------------------
    def _push(self, tok: int) -> None:
        self._q.put(tok)

    def _rewind(self) -> None:
        self.rewinds += 1
        self._q.put(_REWIND)

    def _finish(self) -> None:
        self._terminal.set()
        self._q.put(_END)

    # ---- consumer side ---------------------------------------------------
    def __iter__(self):
        """Yield tokens as they stream in. On a quarantine rewind the
        already-yielded prefix is superseded — ``tokens()`` at the end is
        the authoritative output."""
        while True:
            item = self._q.get()
            if item is _END:
                return
            if item is _REWIND:
                continue
            yield item

    def result(self, timeout: Optional[float] = None) -> Request:
        """Block until the request reaches a terminal state."""
        if not self._terminal.wait(timeout):
            raise TimeoutError(
                f"rid={self.request.rid} not terminal after {timeout}s "
                f"(status={self.request.status})")
        return self.request

    @property
    def status(self) -> str:
        return self.request.status

    def tokens(self) -> List[int]:
        return list(self.request.out)


class FrontDoor:
    """One engine replica behind an async intake.

    The engine thread alternates: drain the intake queue into the
    batcher's admission controller, then run one engine step. Client
    threads only ever touch the thread-safe intake queue — the batcher
    itself stays single-threaded, so every PR 6 invariant (deterministic
    shed sets, quarantine bisection, retrace bounds) holds unchanged.
    """

    def __init__(self, batcher: ContinuousBatcher, intake_bound: int = 256,
                 idle_sleep_s: float = 0.001):
        self.batcher = batcher
        self.intake_bound = intake_bound
        self.idle_sleep_s = idle_sleep_s
        self._intake: "queue.Queue" = queue.Queue(maxsize=intake_bound)
        self._streams: Dict[int, TokenStream] = {}     # id(Request) -> stream
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._idle = threading.Event()
        batcher.on_token = self._on_token
        batcher.on_terminal = self._on_terminal
        batcher.on_rewind = self._on_rewind

    # ---- engine-thread hooks ---------------------------------------------
    def _on_token(self, req: Request, tok: int) -> None:
        s = self._streams.get(id(req))
        if s is not None:
            s._push(tok)

    def _on_terminal(self, req: Request) -> None:
        s = self._streams.pop(id(req), None)
        if s is not None:
            s._finish()

    def _on_rewind(self, req: Request) -> None:
        s = self._streams.get(id(req))
        if s is not None:
            s._rewind()

    # ---- client side -----------------------------------------------------
    def start(self) -> "FrontDoor":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="serve-engine",
                                            daemon=True)
            self._thread.start()
        return self

    def submit(self, tokens: np.ndarray, n_new: int,
               deadline_s: Optional[float] = None,
               rid: int = -1) -> Optional[TokenStream]:
        """Offer a request. Returns a :class:`TokenStream`, or ``None``
        when the intake queue is full (backpressure at the door — the
        caller/router spills to another replica immediately instead of
        queueing behind a busy engine)."""
        req = Request(rid=rid, tokens=np.asarray(tokens, dtype=np.int32),
                      n_new=n_new, deadline_s=deadline_s)
        stream = TokenStream(req)
        self._streams[id(req)] = stream
        try:
            self._intake.put_nowait(req)
        except queue.Full:
            self._streams.pop(id(req), None)
            return None
        self._idle.clear()
        return stream

    def load(self) -> int:
        """Routing signal: work queued at the door + work queued/running
        in the engine (intake depth, admission-queue depth, busy slots)."""
        busy = sum(1 for s in self.batcher.slots if s is not None)
        return self._intake.qsize() + len(self.batcher.queue) + busy

    def pending(self) -> int:
        return self.load()

    # ---- engine loop -----------------------------------------------------
    def _pump_intake(self) -> int:
        moved = 0
        while True:
            try:
                req = self._intake.get_nowait()
            except queue.Empty:
                return moved
            if not self.batcher.submit(req):
                # admission backpressure (max_queue): terminal immediately
                self._on_terminal(req)
            moved += 1

    def _loop(self) -> None:
        while True:
            moved = self._pump_intake()
            stepped = self.batcher.step()
            busy = (moved or stepped or self.batcher.queue
                    or any(s is not None for s in self.batcher.slots)
                    or not self._intake.empty())
            if not busy:
                self._idle.set()
                if self._stop.is_set():
                    return
                time.sleep(self.idle_sleep_s)
            else:
                self._idle.clear()

    def drain(self, timeout: Optional[float] = None) -> DrainResult:
        """Wait until the intake, admission queue and slots are all empty
        (or ``timeout`` elapses), then assemble the same
        :class:`DrainResult` ``run_until_drained`` returns."""
        deadline = None if timeout is None else time.monotonic() + timeout
        status = "drained"
        while not self._idle.is_set():
            wait = (None if deadline is None
                    else max(0.0, deadline - time.monotonic()))
            if not self._idle.wait(timeout=wait or 0.05) \
                    and deadline is not None and time.monotonic() > deadline:
                status = "timeout"
                break
        cb = self.batcher
        undrained = ([r for r in cb.slots if r is not None]
                     + list(cb.queue) + list(self._intake.queue))
        if status == "timeout" and not undrained:
            status = "drained"
        if status != "drained":
            cb.dump_flight(status, {"intake_depth": self._intake.qsize(),
                                    "undrained_rids": [r.rid
                                                       for r in undrained]})
        return DrainResult(cb.done, status, undrained,
                           shed=list(cb.admission.shed),
                           rejected=list(cb.admission.rejected),
                           failed=list(cb.failed))

    def close(self, timeout: float = 30.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def metrics(self) -> Dict:
        out = self.batcher.metrics()
        out["intake_depth"] = self._intake.qsize()
        return out


def merge_drain_results(results: Sequence[DrainResult]) -> DrainResult:
    """Fold per-replica drains into one fleet-level result: lists
    concatenate; the status is the worst across replicas (stalled >
    timeout > drained)."""
    rank = {"drained": 0, "timeout": 1, "stalled": 2}
    worst = max((r.status for r in results), key=lambda s: rank.get(s, 2),
                default="drained")
    done: List[Request] = []
    undrained: List[Request] = []
    shed: List[Request] = []
    rejected: List[Request] = []
    failed: List[Request] = []
    for r in results:
        done.extend(r)
        undrained.extend(r.undrained)
        shed.extend(r.shed)
        rejected.extend(r.rejected)
        failed.extend(r.failed)
    return DrainResult(done, worst, undrained, shed, rejected, failed)


class Router:
    """One submit surface over N replicas.

    Routing is deterministic given the observed loads: replicas are
    tried least-loaded-first (ties broken by replica index), and a
    replica that pushes back (full intake) is skipped for the next one —
    the explicit backpressure contract from PR 6 is exactly what makes
    spilling safe. A submit returns ``None`` only when *every* replica
    pushed back."""

    def __init__(self, doors: Sequence[FrontDoor]):
        if not doors:
            raise ValueError("Router needs at least one FrontDoor")
        self.doors = list(doors)
        self._rid = 0
        self._lock = threading.Lock()

    def start(self) -> "Router":
        for d in self.doors:
            d.start()
        return self

    def submit(self, tokens: np.ndarray, n_new: int,
               deadline_s: Optional[float] = None,
               rid: Optional[int] = None) -> Optional[TokenStream]:
        with self._lock:
            if rid is None:
                rid = self._rid
            self._rid = max(self._rid, rid) + 1
        order = sorted(range(len(self.doors)),
                       key=lambda i: (self.doors[i].load(), i))
        for i in order:
            stream = self.doors[i].submit(tokens, n_new,
                                          deadline_s=deadline_s, rid=rid)
            if stream is not None:
                return stream
        return None

    def drain_all(self, timeout: Optional[float] = None) -> DrainResult:
        return merge_drain_results([d.drain(timeout=timeout)
                                    for d in self.doors])

    def close(self) -> None:
        for d in self.doors:
            d.close()

    def metrics(self) -> List[Dict]:
        return [d.metrics() for d in self.doors]
