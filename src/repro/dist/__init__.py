"""Distribution layer: logical-axis sharding rules (``sharding``) and the
fault-tolerance primitives (``ft``) — heartbeats, stall detection, and
speculative data sharding (DESIGN.md §5)."""
from repro.dist import ft, sharding  # noqa: F401
