"""Fault tolerance (DESIGN.md §5): file-based worker heartbeats with stall
detection, and deterministic row sharding with a speculative-execution
variant (a healthy worker re-derives a straggler's shard without any
coordination — both sides compute the same rows from the same counters).

Stall detection comes in two flavors:

* ``detect_stalled`` — stateless wall-clock scan. A heartbeat whose
  recorded wall time is older than the deadline is stalled. Unreadable
  payloads (a torn write that raced the scan, a corrupted disk block)
  fall back to the FILE MTIME rather than treating the worker as dead —
  mtime is written by the same ``os.replace`` that publishes the payload,
  so it is a faithful lower bound on liveness even when the bytes are not.
* ``StallDetector`` — stateful progress scan for long-lived monitors.
  Workers publish a monotonic ``seq`` counter with every beat; the
  detector remembers the last counter it saw per worker and flags a
  worker only when its counter has not advanced for ``deadline_s`` of the
  READER's monotonic clock. Wall-clock skew between writer and reader
  (NTP steps, container clock drift) cannot misclassify a worker, because
  no cross-host timestamps are ever compared.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import numpy as np


class HeartbeatError(RuntimeError):
    """A heartbeat file exists but its payload cannot be trusted
    (torn/corrupt JSON, vanished mid-read)."""


class Heartbeat:
    """One JSON heartbeat file per worker; ``beat`` is atomic (tmp+rename)
    so a reader never sees a torn write. Every beat carries a
    monotonically increasing ``seq`` counter (progress signal for
    ``StallDetector``) alongside the wall-clock ``time`` (human-readable
    and used by the stateless ``detect_stalled`` scan).

    ``fault`` (optional) is a fault-injection plan
    (``dist.faultinject.FaultPlan``): per-beat it may suppress the write
    (simulating a wedged worker) or tear it (a non-atomic partial write,
    which the atomic rename path can never produce on its own).
    """

    def __init__(self, path: str, worker_id: int = 0, fault=None):
        self.path = path
        self.worker_id = worker_id
        self.fault = fault
        self.seq = 0
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)

    def beat(self, step: int) -> None:
        self.seq += 1
        mode = (self.fault.heartbeat_mode(self.seq)
                if self.fault is not None else "ok")
        if mode == "skip":          # wedged worker: no write at all
            return
        payload = {"worker_id": self.worker_id, "step": int(step),
                   "seq": self.seq, "time": time.time()}
        if mode == "torn":          # simulated torn write: truncated JSON,
            raw = json.dumps(payload)[:13]      # written IN PLACE (no
            with open(self.path, "w") as f:     # tmp+rename atomicity)
                f.write(raw)
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def read(self) -> Dict:
        """Read back the last payload. Raises ``HeartbeatError`` (not a
        raw ``JSONDecodeError``) when the file is torn or unreadable, so
        callers can distinguish 'worker never started' (FileNotFoundError)
        from 'worker is writing garbage'."""
        try:
            with open(self.path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            raise HeartbeatError(
                f"heartbeat {self.path} unreadable: {e}") from e


def _payload_or_none(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError, UnicodeDecodeError):
        return None


def detect_stalled(root: str, deadline_s: float) -> List[str]:
    """Names of heartbeat files under `root` older than `deadline_s`.

    Unreadable payloads (torn writes) fall back to the file mtime — the
    old behavior of treating them as ``t=0`` misclassified a live worker
    as stalled the instant a scan raced a (simulated) torn write.
    In-flight ``.tmp`` files are ignored: they are the atomic-rename
    staging area, never the published heartbeat."""
    stalled = []
    now = time.time()
    for name in sorted(os.listdir(root)):
        path = os.path.join(root, name)
        if not os.path.isfile(path) or name.endswith(".tmp"):
            continue
        payload = _payload_or_none(path)
        if payload is not None and "time" in payload:
            t = float(payload["time"])
        else:
            try:
                t = os.path.getmtime(path)
            except OSError:
                continue            # vanished mid-scan: next scan decides
        if now - t > deadline_s:
            stalled.append(name)
    return stalled


class StallDetector:
    """Progress-based stall detection, immune to wall-clock skew.

    ``poll()`` scans the heartbeat directory; a worker is stalled when its
    ``seq`` counter (falling back to ``step``, then file mtime for torn
    payloads) has not advanced for ``deadline_s`` measured on the
    READER's ``time.monotonic()`` clock. First sight of a worker starts
    its grace window — a worker is never declared stalled on the very
    first scan."""

    def __init__(self, root: str, deadline_s: float):
        self.root = root
        self.deadline_s = deadline_s
        # name -> (last progress marker, reader-monotonic time it changed)
        self._seen: Dict[str, tuple] = {}

    def _marker(self, path: str):
        payload = _payload_or_none(path)
        if payload is not None:
            return (payload.get("seq"), payload.get("step"))
        try:
            return ("mtime", os.path.getmtime(path))
        except OSError:
            return None

    def poll(self) -> List[str]:
        now = time.monotonic()
        stalled = []
        for name in sorted(os.listdir(self.root)):
            path = os.path.join(self.root, name)
            if not os.path.isfile(path) or name.endswith(".tmp"):
                continue
            marker = self._marker(path)
            if marker is None:
                continue
            last = self._seen.get(name)
            if last is None or last[0] != marker:
                self._seen[name] = (marker, now)
                continue
            if now - last[1] > self.deadline_s:
                stalled.append(name)
        return stalled


def shard_rows(n_rows: int, num_shards: int, shard_id: int) -> np.ndarray:
    """Strided row assignment: disjoint across shards, covers [0, n_rows)."""
    return np.arange(shard_id, n_rows, num_shards)


def speculative_shard(n_rows: int, num_shards: int, shard_id: int,
                      spare: int = 0) -> np.ndarray:
    """Rows worker `shard_id` computes when speculating `spare` hops ahead:
    spare=0 is its own shard; spare=k re-derives the shard of the worker k
    positions over (used to cover a straggler detected via heartbeats)."""
    return shard_rows(n_rows, num_shards, (shard_id + spare) % num_shards)
