"""Fault tolerance (DESIGN.md §5): file-based worker heartbeats with stall
detection, and deterministic row sharding with a speculative-execution
variant (a healthy worker re-derives a straggler's shard without any
coordination — both sides compute the same rows from the same counters)."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import numpy as np


class Heartbeat:
    """One JSON heartbeat file per worker; ``beat`` is atomic (tmp+rename)
    so a reader never sees a torn write."""

    def __init__(self, path: str, worker_id: int = 0):
        self.path = path
        self.worker_id = worker_id
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)

    def beat(self, step: int) -> None:
        payload = {"worker_id": self.worker_id, "step": int(step),
                   "time": time.time()}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def read(self) -> Dict:
        with open(self.path) as f:
            return json.load(f)


def detect_stalled(root: str, deadline_s: float) -> List[str]:
    """Names of heartbeat files under `root` older than `deadline_s`."""
    stalled = []
    now = time.time()
    for name in sorted(os.listdir(root)):
        path = os.path.join(root, name)
        if not os.path.isfile(path) or name.endswith(".tmp"):
            continue
        try:
            with open(path) as f:
                t = json.load(f).get("time", 0.0)
        except (json.JSONDecodeError, OSError):
            t = 0.0
        if now - t > deadline_s:
            stalled.append(name)
    return stalled


def shard_rows(n_rows: int, num_shards: int, shard_id: int) -> np.ndarray:
    """Strided row assignment: disjoint across shards, covers [0, n_rows)."""
    return np.arange(shard_id, n_rows, num_shards)


def speculative_shard(n_rows: int, num_shards: int, shard_id: int,
                      spare: int = 0) -> np.ndarray:
    """Rows worker `shard_id` computes when speculating `spare` hops ahead:
    spare=0 is its own shard; spare=k re-derives the shard of the worker k
    positions over (used to cover a straggler detected via heartbeats)."""
    return shard_rows(n_rows, num_shards, (shard_id + spare) % num_shards)
