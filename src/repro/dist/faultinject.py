"""Deterministic fault injection for the serving stack (DESIGN.md §5).

A ``FaultPlan`` is a declarative, seed-deterministic description of the
faults a run should experience. The engine (``serve.engine``), the
heartbeat writer (``dist.ft.Heartbeat``) and the launcher
(``launch/serve.py --fault-plan``) all consult the SAME plan object, so a
chaos test can replay a faulted run bit-for-bit: every injector fires at
a configured step counter (never from wall clock or ambient randomness),
and any randomized choice (which slot to poison) derives from
``numpy.random.default_rng(seed + step)``.

Injector classes (ISSUE 6):

* **NaN logits** — ``nan_decode_step`` poisons the decode logits of
  selected rows at one engine step; ``nan_prefill_admission`` poisons
  admitted rows of the Nth batched prefill. ``nan_rows="all"`` poisons
  every live row (exercises the quarantine bisector — row attribution is
  ambiguous). ``poison_rids`` marks requests as PERSISTENTLY poisonous:
  their logits rows are corrupted at every decode/prefill/probe, modeling
  content that reliably breaks the model (these must exhaust the retry
  budget and fail typed, never stall the engine).
* **Slow / wedged step** — ``slow_step``+``slow_s`` sleeps inside one
  engine step (latency spike); ``wedge_from_step`` makes every later step
  a no-op that sleeps ``wedge_s`` (a hung engine: the drain watchdog must
  classify the run as *stalled*, not loop forever).
* **Heartbeat faults** — ``hb_skip_from``/``hb_torn_at`` are consumed by
  ``ft.Heartbeat`` (suppressed beat / torn in-place write).
* **Checkpoint corruption** — ``corrupt_artifact`` flips one
  seed-deterministic bit of (or truncates) a saved artifact's array blob,
  which the sha256 manifest verification must catch at load time.
* **Queue flood** — ``flood_requests`` builds a seed-deterministic burst
  of requests to slam past the admission bound.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class FaultPlan:
    seed: int = 0

    # --- NaN-logit injectors ---------------------------------------------
    nan_decode_step: int = -1      # engine step index to poison (-1 = off)
    nan_rows: Tuple[int, ...] | str = ()   # slot rows; () = one seeded row;
    #                                        "all" = every live row
    nan_prefill_admission: int = -1   # Nth batched prefill (0-based)
    poison_rids: Tuple[int, ...] = ()  # rids poisoned at EVERY opportunity

    # --- timing injectors -------------------------------------------------
    slow_step: int = -1
    slow_s: float = 0.0
    wedge_from_step: int = -1      # from this step on, step() does nothing
    wedge_s: float = 0.01          # per-wedged-step sleep

    # --- heartbeat injectors ----------------------------------------------
    hb_skip_from: int = -1         # suppress beats from this seq on
    hb_torn_at: int = -1           # tear exactly this beat (in-place write)

    # bookkeeping: which injectors actually fired (assertable in tests)
    fired: List[str] = field(default_factory=list, repr=False)

    # ---- (de)serialization (launch/serve.py --fault-plan) ----------------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d.pop("fired")
        return json.dumps(d)

    @staticmethod
    def from_json(spec: str) -> "FaultPlan":
        """Parse a plan from a JSON string, or from a file via ``@path``."""
        if spec.startswith("@"):
            with open(spec[1:]) as f:
                spec = f.read()
        d = json.loads(spec)
        for k in ("nan_rows", "poison_rids"):
            if isinstance(d.get(k), list):
                d[k] = tuple(d[k])
        return FaultPlan(**d)

    def _note(self, what: str) -> None:
        self.fired.append(what)

    # ---- engine hooks ----------------------------------------------------
    def decode_rows_to_poison(self, step_idx: int,
                              live_rows: Sequence[int]) -> List[int]:
        """Rows of this decode step's logits to corrupt. Deterministic:
        the one-shot injector fires exactly at ``nan_decode_step``; the
        row choice (when not pinned) is seeded by (seed, step)."""
        rows: List[int] = []
        if step_idx == self.nan_decode_step and live_rows:
            if self.nan_rows == "all":
                rows = list(live_rows)
            elif self.nan_rows:
                rows = [r for r in self.nan_rows if r in live_rows]
            else:
                rng = np.random.default_rng(self.seed + step_idx)
                rows = [int(rng.choice(np.asarray(live_rows)))]
            if rows:
                self._note(f"nan_decode@{step_idx}:{rows}")
        return rows

    def prefill_rows_to_poison(self, admission_idx: int,
                               n_rows: int) -> List[int]:
        """Rows of the ``admission_idx``-th batched prefill to corrupt."""
        rows: List[int] = []
        if admission_idx == self.nan_prefill_admission and n_rows:
            if self.nan_rows == "all":
                rows = list(range(n_rows))
            elif self.nan_rows:
                rows = [r for r in self.nan_rows if r < n_rows]
            else:
                rng = np.random.default_rng(self.seed + 7919 + admission_idx)
                rows = [int(rng.integers(n_rows))]
            if rows:
                self._note(f"nan_prefill@{admission_idx}:{rows}")
        return rows

    def rid_is_poison(self, rid: int) -> bool:
        """Persistent content poison: fires on every decode, prefill and
        quarantine probe touching this rid."""
        return rid in self.poison_rids

    def stall_for(self, step_idx: int) -> float:
        if step_idx == self.slow_step and self.slow_s > 0:
            self._note(f"slow@{step_idx}:{self.slow_s}s")
            return self.slow_s
        return 0.0

    def wedged(self, step_idx: int) -> bool:
        if self.wedge_from_step >= 0 and step_idx >= self.wedge_from_step:
            self._note(f"wedge@{step_idx}")
            time.sleep(self.wedge_s)
            return True
        return False

    # ---- heartbeat hook (ft.Heartbeat) -----------------------------------
    def heartbeat_mode(self, seq: int) -> str:
        if self.hb_skip_from >= 0 and seq >= self.hb_skip_from:
            self._note(f"hb_skip@{seq}")
            return "skip"
        if seq == self.hb_torn_at:
            self._note(f"hb_torn@{seq}")
            return "torn"
        return "ok"


# ---------------------------------------------------------------------------
# Checkpoint corruption (operates on a saved store.save_pytree artifact)
# ---------------------------------------------------------------------------
def corrupt_artifact(artifact_dir: str, kind: str = "bitflip",
                     seed: int = 0) -> str:
    """Corrupt the array blob of a saved artifact in a seed-deterministic
    way. ``kind``:

    * ``bitflip`` — flip one bit at a seeded offset in the back half of
      ``arrays.npz`` (array data, not the zip header — the file still
      opens, one tensor's bytes change; only the sha256 manifest check
      can catch it)
    * ``truncate`` — drop the final 25% of the file (a torn copy; numpy
      fails to open it, or opens with missing members)

    Returns the path of the file it corrupted.
    """
    path = os.path.join(artifact_dir, "arrays.npz")
    with open(path, "rb") as f:
        blob = bytearray(f.read())
    if kind == "bitflip":
        rng = np.random.default_rng(seed)
        lo = len(blob) // 2
        off = int(rng.integers(lo, len(blob)))
        blob[off] ^= 1 << int(rng.integers(8))
        with open(path, "wb") as f:
            f.write(blob)
    elif kind == "truncate":
        with open(path, "wb") as f:
            f.write(bytes(blob[:max(1, (len(blob) * 3) // 4)]))
    else:
        raise ValueError(f"unknown corruption kind {kind!r}")
    return path


# ---------------------------------------------------------------------------
# Queue flood
# ---------------------------------------------------------------------------
def flood_requests(n: int, vocab_size: int, prompt_len: int = 8,
                   n_new: int = 4, seed: int = 0, rid_base: int = 10_000,
                   deadline_s: Optional[float] = None) -> List:
    """A seed-deterministic burst of requests for flooding the admission
    queue past its bound (imported lazily to keep dist/ free of a serve/
    dependency at module import)."""
    from repro.serve.engine import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=rid_base + i,
                    tokens=rng.integers(0, vocab_size, size=(prompt_len,),
                                        dtype=np.int32),
                    n_new=n_new, deadline_s=deadline_s)
            for i in range(n)]
