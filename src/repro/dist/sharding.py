"""Logical-axis sharding: model code annotates arrays with *logical* names
("batch", "mlp", "kv_seq", ...) and this module resolves them against
whatever mesh is active — production (pod, data, model), host test meshes,
or none at all (annotations become no-ops on a single device).

Resolution is rule-driven and shape-aware: a logical name maps to an
ordered tuple of mesh axes; axes missing from the mesh fold away, axes
already consumed by an earlier dimension are skipped (first dim wins), and
``shape_aware_spec`` additionally drops axes whose combined size does not
divide the dimension (e.g. 8 kv heads on a 16-way model axis replicate
instead of erroring)."""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Tuple

import jax

try:                                    # jax >= 0.6 re-exports at top level
    from jax import shard_map
except ImportError:                     # 0.4.x: experimental only
    from jax.experimental.shard_map import shard_map  # noqa: F401

P = jax.sharding.PartitionSpec

# logical name -> ordered mesh axes (leftmost first; missing axes fold away)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "embed": ("data",),
    "seq": ("model",),
    "kv_seq": ("model",),
    "kv_seq_model": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "conv": (),
    "layer_stack": (),
    # leading axis of stacked same-shape compression-group batches
    # (core.compress device path): spread whole groups over the data
    # axes; replicates when the bucket doesn't divide (shape_aware_spec)
    "group_batch": ("pod", "data"),
    # streaming-calibration accumulators (core.capture mesh path):
    # "calib_shard" is the per-shard stacking axis of streaming-whitening
    # QR factors (one (d, d) factor per data shard, tree-reduced at
    # finalize); "gram_rows" is the row dimension of sharded (D, D) Gram
    # accumulators — each device holds a (D/n_shards, D) block and folds
    # its rows of XᵀX from all-gathered activations (DESIGN.md §1.6)
    "calib_shard": ("pod", "data"),
    "gram_rows": ("pod", "data"),
}

_CTX = threading.local()


def _rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_CTX, "rules", DEFAULT_RULES)


def current_mesh():
    return getattr(_CTX, "mesh", None)


class use_rules:
    """Context manager: overlay `rules` on the defaults and (optionally)
    pin the mesh that ``constrain`` resolves against."""

    def __init__(self, rules: Optional[Dict] = None, mesh=None):
        self._rules = dict(DEFAULT_RULES)
        self._rules.update(rules or {})
        self._mesh = mesh

    def __enter__(self):
        self._prev = (getattr(_CTX, "rules", None),
                      getattr(_CTX, "mesh", None))
        _CTX.rules = self._rules
        _CTX.mesh = self._mesh
        return self

    def __exit__(self, *exc):
        _CTX.rules, _CTX.mesh = self._prev
        return False


def _mesh_axes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _resolve(name: Optional[str], mesh_shape: Dict[str, int],
             used: set) -> Tuple[str, ...]:
    if name is None:
        return ()
    want = _rules().get(name, ())
    return tuple(a for a in want if a in mesh_shape and a not in used)


def _entry(axes: Tuple[str, ...]):
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return axes


def logical_spec(axes: Sequence[Optional[str]], mesh) -> P:
    """Resolve logical names to a PartitionSpec (no shape checks)."""
    mesh_shape = _mesh_axes(mesh)
    used: set = set()
    entries = []
    for name in axes:
        got = _resolve(name, mesh_shape, used)
        used.update(got)
        entries.append(_entry(got))
    return P(*entries)


def shape_aware_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                     mesh) -> P:
    """Like ``logical_spec`` but drops (from the right) mesh axes whose
    combined size does not evenly divide the array dimension, so awkward
    shapes replicate instead of failing to lower."""
    assert len(shape) == len(axes), (shape, axes)
    mesh_shape = _mesh_axes(mesh)
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        resolved = _resolve(name, mesh_shape, used)
        got = resolved
        while got:
            total = 1
            for a in got:
                total *= mesh_shape[a]
            if dim % total == 0:
                break
            got = got[:-1]
        used.update(got)
        # a divisibility-reduced composite keeps its tuple form (partial
        # sharding of a folded axis group); plain resolutions unwrap
        entries.append(got if got and got != resolved else _entry(got))
    return P(*entries)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate `x` with logical axes. No-op unless a mesh is active
    (``use_rules(..., mesh=...)``)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = shape_aware_spec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def axis_group_size(mesh, axes: Sequence[str]) -> int:
    """Total number of shards along a folded mesh-axis group."""
    size = 1
    for a in axes:
        size *= dict(mesh.shape)[a]
    return size


def combined_axis_index(axes: Sequence[str], mesh) -> jax.Array:
    """Row-major linear shard index along a folded axis group — the
    ``shard_map``-body counterpart of folding several mesh axes into one
    PartitionSpec entry (e.g. the sharded-Gram row blocks: the block a
    device owns is ``combined_axis_index * block_rows``)."""
    mesh_shape = dict(mesh.shape)
    idx = None
    for a in axes:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * mesh_shape[a] + i
    return idx if idx is not None else 0


def shardings_for_tree(params, specs, mesh):
    """NamedSharding tree for a (params, axis-name specs) tree pair. Works
    on concrete arrays or ShapeDtypeStructs (abstract dry-runs)."""
    return jax.tree.map(
        lambda v, s: jax.sharding.NamedSharding(
            mesh, shape_aware_spec(v.shape, s, mesh)),
        params, specs)
