"""Model / run configuration system.

Every assigned architecture is expressed as a single ``ModelConfig``; the
model substrate (``repro.models``) interprets it. Configs are plain frozen
dataclasses so they hash/compare cleanly and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


# ---------------------------------------------------------------------------
# Layer-kind schedule
# ---------------------------------------------------------------------------
# A model is a sequence of layer "kinds". Consecutive runs of the same kind
# are stacked and executed with lax.scan (compile-time compactness); distinct
# kinds break the stack. Kinds:
#   "attn"        full-attention transformer block
#   "swa"         sliding-window-attention transformer block
#   "mlstm"       xLSTM mLSTM block (matrix memory)
#   "slstm"       xLSTM sLSTM block (scalar memory)
#   "hymba"       parallel attention+mamba block (window attn)
#   "hymba_g"     parallel attention+mamba block (global attn)
ATTN_KINDS = ("attn", "swa", "hymba", "hymba_g")
SSM_KINDS = ("mlstm", "slstm")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0            # per-expert FFN hidden dim
    num_shared: int = 0          # shared (always-on) experts
    d_shared: int = 0            # hidden dim of each shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # pad experts so EP divides evenly (router masks padding to -inf)
    pad_to: int = 0

    @property
    def padded_experts(self) -> int:
        return max(self.num_experts, self.pad_to)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # --- attention flavour ---
    rope_kind: str = "rope"      # rope | mrope | none
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # gemma3 local layers use a different theta
    mrope_sections: Tuple[int, ...] = ()   # per-component head_dim split (t,h,w)
    qk_norm: bool = False
    sliding_window: int = 0      # >0 enables SWA for "swa"/"hymba" kinds
    local_global_pattern: Tuple[int, int] = (0, 0)  # (n_local, n_global) per superblock
    attn_logit_softcap: float = 0.0

    # --- FFN / MoE ---
    mlp_kind: str = "swiglu"     # swiglu | gelu | none
    moe: MoEConfig = field(default_factory=MoEConfig)
    moe_every: int = 1           # MoE layer frequency (1 = every layer)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    mlstm_every_slstm: int = 0   # xlstm: one sLSTM per this many layers (0 = none)

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend stub ---
    frontend: str = ""           # "" | vision | audio

    # --- numerics / embeddings ---
    tie_embeddings: bool = False
    scale_embed: bool = False    # multiply embeddings by sqrt(d_model) (gemma)
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"
    logit_softcap: float = 0.0
    norm_eps: float = 1e-6

    # --- distribution knobs (perf-iteration surface) ---
    remat: str = "block"         # none | block | full
    scan_layers: bool = True
    shard_attn_heads: bool = True   # TP over head dims (uneven dims padded by SPMD)
    sequence_parallel: bool = True  # shard residual-stream seq dim over model axis

    # --- D-Rank / low-rank deployment ---
    # When a compression plan is attached (see repro.core.plan), linears are
    # FactorizedLinear{B,C}. rank_multiple MXU-aligns allocated ranks.
    rank_multiple: int = 128

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived ----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind schedule for the decoder stack."""
        kinds = []
        nl, ng = self.local_global_pattern
        for i in range(self.n_layers):
            if self.family == "ssm":
                if self.mlstm_every_slstm and (i % self.mlstm_every_slstm
                                               == self.mlstm_every_slstm - 1):
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.family == "hybrid":
                # Hymba: global full attention at first/middle/last layer
                if i in (0, self.n_layers // 2, self.n_layers - 1):
                    kinds.append("hymba_g")
                else:
                    kinds.append("hymba")
            elif nl and ng:
                # gemma3-style: nl local then ng global, repeating
                kinds.append("swa" if (i % (nl + ng)) < nl else "attn")
            elif self.sliding_window:
                kinds.append("swa")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def layer_runs(self) -> Tuple[Tuple[str, int], ...]:
        """Consecutive same-kind runs: ((kind, length), ...)."""
        runs = []
        for k in self.layer_kinds():
            if runs and runs[-1][0] == k:
                runs[-1][1] += 1
            else:
                runs.append([k, 1])
        return tuple((k, n) for k, n in runs)

    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        if self.family in ("ssm", "hybrid"):
            return True
        nl, ng = self.local_global_pattern
        return bool(nl and ng)  # local:global mix (gemma3) qualifies

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            dtype="float32",
            param_dtype="float32",
            rank_multiple=4,
            sequence_parallel=False,
        )
        if self.moe.num_experts:
            changes["moe"] = MoEConfig(
                num_experts=4, top_k=2, d_expert=32,
                num_shared=min(self.moe.num_shared, 1), d_shared=32,
                capacity_factor=2.0, pad_to=4)
        if self.mrope_sections:
            changes["mrope_sections"] = (2, 3, 3)   # sums to head_dim 16 // 2
        if self.local_global_pattern != (0, 0):
            changes["local_global_pattern"] = (1, 1)
            changes["n_layers"] = 4
        if self.sliding_window:
            changes["sliding_window"] = 8
        if self.is_encoder_decoder:
            changes["n_encoder_layers"] = 2
            changes["n_layers"] = 2
        if self.mlstm_every_slstm:
            changes["mlstm_every_slstm"] = 2
        if self.ssm_state:
            changes["ssm_state"] = 4
        changes.update(overrides)
        return dataclasses.replace(self, **changes)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for LM-family archs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason-if-not). Mirrors DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention"
    return True, ""
