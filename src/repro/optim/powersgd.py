"""PowerSGD-style low-rank gradient compression with error feedback —
the cross-pod (DCI) distributed-optimization trick (DESIGN.md §5).

Each >=2-D gradient leaf M (d1, d2) is factorized as M ≈ P Qᵀ with
P (d1, r), Q (d2, r): workers all-reduce the factors (r·(d1+d2) bytes)
instead of the dense gradient (d1·d2 bytes) — a (d1·d2)/(r(d1+d2))×
reduction on the slow inter-pod links. The residual M − P Qᵀ is kept in
local *error feedback* state and re-injected next step, which restores
convergence (Vogels et al., 2019).

Beyond-paper synergy: the per-leaf rank is allocated with the SAME
effective-rank Lagrange machinery the paper uses for weights — gradients of
information-dense layers get more rank under a fixed byte budget
(``allocate_ranks_by_reff``).

The reduction itself is expressed with ``jax.lax.psum`` inside a
``shard_map`` over the data-parallel axes (``cross_pod_mean``); on a single
device the psum is the identity and the same code path is exercised by
tests via a vmapped multi-worker simulation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocate as alloc


@dataclass(frozen=True)
class PowerSGDConfig:
    rank: int = 4
    min_dim: int = 64          # leaves smaller than this stay dense
    ef: bool = True            # error feedback
    warm_start: bool = True    # reuse Q across steps


class PowerSGDState(NamedTuple):
    error: Dict                # error-feedback residuals (dense leaves)
    q: Dict                    # warm-start Q factors


def _compressible(x) -> bool:
    return x.ndim >= 2 and min(x.shape[-2], x.shape[-1]) >= 2


def _as2d(x):
    return x.reshape(-1, x.shape[-1])


def _orthonormalize(P: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR (fp32)."""
    q, _ = jnp.linalg.qr(P.astype(jnp.float32))
    return q


def init_state(grads, cfg: PowerSGDConfig,
               ranks: Optional[Dict[str, int]] = None,
               key: Optional[jax.Array] = None) -> PowerSGDState:
    key = key if key is not None else jax.random.PRNGKey(17)
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    err, qs = {}, {}
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        if not _compressible(leaf) or min(
                _as2d(leaf).shape) < cfg.min_dim:
            continue
        r = (ranks or {}).get(name, cfg.rank)
        r = max(1, min(r, min(_as2d(leaf).shape)))
        err[name] = jnp.zeros_like(leaf, dtype=jnp.float32)
        qs[name] = jax.random.normal(jax.random.fold_in(key, i),
                                     (_as2d(leaf).shape[1], r),
                                     dtype=jnp.float32)
    return PowerSGDState(error=err, q=qs)


def compress_decompress(grads, state: PowerSGDState, cfg: PowerSGDConfig,
                        reduce_fn=None
                        ) -> Tuple[Dict, PowerSGDState, Dict[str, float]]:
    """One round: per compressible leaf, factorize (grad + error), reduce the
    factors with `reduce_fn` (e.g. a psum-mean over the pod axis; identity if
    None), reconstruct, update error feedback. Dense leaves pass through
    `reduce_fn` untouched (they'd ride the intra-pod reduction in deploy).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    out_leaves = []
    new_err = dict(state.error)
    new_q = dict(state.q)
    dense_bytes = 0
    comp_bytes = 0
    rf = reduce_fn if reduce_fn is not None else (lambda x: x)
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if name not in state.q:
            out_leaves.append(rf(leaf))
            continue
        M = _as2d(leaf.astype(jnp.float32))
        if cfg.ef:
            M = M + _as2d(state.error[name])
        Q = state.q[name]
        P = _orthonormalize(rf(M @ Q))           # (d1, r), reduced
        Qn = rf(M.T @ P)                          # (d2, r), reduced
        Mhat = P @ Qn.T
        if cfg.ef:
            new_err[name] = (M - Mhat).reshape(leaf.shape)
        new_q[name] = Qn if cfg.warm_start else Q
        out_leaves.append(Mhat.reshape(leaf.shape).astype(leaf.dtype))
        dense_bytes += M.size * 4
        comp_bytes += (P.size + Qn.size) * 4
    out = jax.tree_util.tree_unflatten(treedef, out_leaves)
    stats = {"dense_bytes": float(dense_bytes),
             "compressed_bytes": float(comp_bytes),
             "byte_reduction": float(dense_bytes / max(1, comp_bytes))}
    return out, PowerSGDState(error=new_err, q=new_q), stats


def cross_pod_mean(mesh, axis: str = "pod"):
    """Returns a reduce_fn performing a mean-psum over `axis` for use inside
    an enclosing shard_map; identity when the axis is absent."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return lambda x: x

    def rf(x):
        return jax.lax.pmean(x, axis)
    return rf


def allocate_ranks_by_reff(grads, byte_budget_frac: float,
                           cfg: PowerSGDConfig) -> Dict[str, int]:
    """Beyond-paper: spend a fixed factor-byte budget across leaves in
    proportion to sqrt(R_eff(grad)/ω) — the paper's allocator applied to
    gradient spectra."""
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    specs = []
    names = []
    for path, leaf in flat:
        if not _compressible(leaf) or min(_as2d(leaf).shape) < cfg.min_dim:
            continue
        name = jax.tree_util.keystr(path)
        M = np.asarray(_as2d(leaf), dtype=np.float64)
        sig = np.linalg.svd(M, compute_uv=False)
        from repro.core.numerics import effective_rank
        reff = effective_rank(sig)
        d1, d2 = M.shape
        specs.append(alloc.GroupSpec(
            gid=name, mtype="grad", reff=reff, omega=d1 + d2,
            kmax=min(d1, d2), kmin=1, dense_params=d1 * d2))
        names.append(name)
    if not specs:
        return {}
    budget = byte_budget_frac * sum(s.dense_params for s in specs)
    kf = alloc.lagrange_allocate(specs, budget)
    ki = alloc.integerize(specs, kf, budget, multiple=1)
    return {n: int(ki[n]) for n in names}
