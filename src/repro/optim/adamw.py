"""AdamW (decoupled weight decay), schedules, global-norm clipping.

Written as a minimal optax-style (init, update) pair over pytrees so the
train step can chain transforms; state is a plain pytree (checkpointable,
shardable with the same logical specs as the params)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | linear | constant
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Dict
    nu: Dict


def make_schedule(cfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "linear":
            decay = 1.0 - (1.0 - cfg.min_lr_frac) * t
        else:
            decay = jnp.asarray(1.0)
        return cfg.lr * warm * decay
    return sched


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def _decay_mask(path_leaf: Tuple) -> bool:
    """No weight decay on norms/biases/1-d params (standard practice)."""
    return True


def adamw_update(cfg: OptimizerConfig, grads, state: AdamWState, params,
                 ) -> Tuple[Dict, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, stats)."""
    sched = make_schedule(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.betas
    step = state.step + 1
    lr = sched(state.step)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, mu=mu, nu=nu), stats
