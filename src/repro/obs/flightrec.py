"""Flight recorder: a bounded ring of recent engine events, dumped to a
JSON artifact when something goes wrong (DESIGN.md §6.3).

A drain timeout or a poison bisection used to leave nothing to debug
from — the process exited and the evidence died with it. The recorder
is *always on* (a ``deque(maxlen=...)`` of small dicts costs nothing
measurable next to a decode step) and *only writes* when a trigger
fires and a dump directory is configured:

* a request fails typed (``failed_poison``),
* the drain watchdog trips (``stalled``),
* ``run_until_drained`` returns non-``drained``.

The artifact (``flightrec-<reason>-<n>.json``, schema
``repro.flightrec/v1``) carries everything needed to reproduce the
failure: the armed ``FaultPlan`` (seed included), queue/slot state at
dump time, the elastic rung, the last-N step wall times and the event
ring itself — the chaos suite asserts a poisoned request's rid and the
rung it failed at are recoverable from the dump alone.
"""
from __future__ import annotations

import collections
import json
import os
import tempfile
import threading
from typing import Any, Deque, Dict, List, Optional

SCHEMA = "repro.flightrec/v1"

DEFAULT_EVENTS = 512
DEFAULT_TIMINGS = 64


class FlightRecorder:
    """Ring buffer of recent events + step timings, with triggered dumps.

    ``dump_dir=None`` keeps recording but never writes (the in-memory
    ring is still inspectable — tests and the REPL read ``events``).
    Dumps are atomic and fsync-free (an artifact torn by a crash is
    re-creatable; the *engine* must never block on one).
    """

    def __init__(self, dump_dir: Optional[str] = None,
                 max_events: int = DEFAULT_EVENTS,
                 max_timings: int = DEFAULT_TIMINGS):
        self.dump_dir = dump_dir or None
        self.events: Deque[Dict[str, Any]] = collections.deque(
            maxlen=max_events)
        self.step_timings: Deque[Dict[str, float]] = collections.deque(
            maxlen=max_timings)
        self.dumps: List[str] = []        # paths written this process
        self._seq = 0
        self._lock = threading.Lock()

    # ---- recording (engine thread) ---------------------------------------
    def note(self, kind: str, **fields) -> None:
        """Append one event to the ring. ``kind`` is the event taxonomy
        key (``admit``/``shed``/``poison``/``rung``/``fail``/...);
        fields must be JSON-serializable scalars or small lists."""
        with self._lock:
            ev = {"seq": self._seq, "kind": kind}
            self._seq += 1
            ev.update(fields)
            self.events.append(ev)

    def step_timing(self, step: int, wall_ms: float, live: int) -> None:
        with self._lock:
            self.step_timings.append(
                {"step": step, "wall_ms": round(wall_ms, 3), "live": live})

    # ---- dumping ---------------------------------------------------------
    def dump(self, reason: str, context: Optional[Dict[str, Any]] = None
             ) -> Optional[str]:
        """Write the artifact for ``reason`` and return its path, or
        ``None`` when no dump dir is configured. Never raises — a failed
        dump is reported in-band (the engine must keep serving)."""
        if self.dump_dir is None:
            return None
        with self._lock:
            payload = {
                "schema": SCHEMA,
                "reason": reason,
                "context": context or {},
                "step_timings": list(self.step_timings),
                "events": list(self.events),
            }
            n = len(self.dumps)
        try:
            os.makedirs(self.dump_dir, exist_ok=True)
            path = os.path.join(self.dump_dir,
                                f"flightrec-{reason}-{n}.json")
            fd, tmp = tempfile.mkstemp(dir=self.dump_dir, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, path)
        except OSError:
            return None
        self.dumps.append(path)
        return path


def validate_dump(obj: Dict[str, Any]) -> List[str]:
    """Validate a flight-recorder artifact; returns problems (empty =
    valid). Shared by tests and the CI chaos drill."""
    errs: List[str] = []
    if obj.get("schema") != SCHEMA:
        errs.append(f"bad schema {obj.get('schema')!r} (want {SCHEMA})")
    if not isinstance(obj.get("reason"), str) or not obj.get("reason"):
        errs.append("missing reason")
    if not isinstance(obj.get("context"), dict):
        errs.append("context missing or not an object")
    evs = obj.get("events")
    if not isinstance(evs, list):
        errs.append("events missing or not a list")
    else:
        for i, ev in enumerate(evs):
            if not isinstance(ev, dict) or "kind" not in ev \
                    or "seq" not in ev:
                errs.append(f"events[{i}]: missing kind/seq")
                break
        seqs = [ev.get("seq") for ev in evs if isinstance(ev, dict)]
        if seqs != sorted(seqs):
            errs.append("event seqs not monotonic")
    if not isinstance(obj.get("step_timings"), list):
        errs.append("step_timings missing or not a list")
    return errs
