"""Typed metrics registry: counters, gauges and bounded-reservoir
histograms behind one versioned snapshot schema (DESIGN.md §6.2).

Serving stats used to live in three ad-hoc shapes —
``ServeMetrics.snapshot()``, ``ContinuousBatcher.stats`` and the AOT
``aot_*`` counters — and the latency samples behind the percentile
helpers grew one float per request, forever. This module gives them one
home:

* **Counter / Gauge / Histogram** are the only metric types. A
  histogram is a *bounded reservoir* (Vitter's Algorithm R with a
  deterministic per-name seed): memory is O(capacity) no matter how
  many samples arrive, every sample still updates exact ``n``/``sum``/
  ``min``/``max``, and percentiles come from the uniform reservoir.
* **MetricsRegistry.snapshot()** emits the versioned schema
  ``{"schema": "repro.serve.metrics/v2", "counters": ..., "gauges":
  ..., "histograms": ...}`` — the one shape ``--stats-json``, the
  periodic exporter and the tests all consume.
* **Exposition**: :func:`prometheus_text` renders a snapshot in the
  Prometheus text format (served by :class:`MetricsServer` on
  ``--metrics-port``); :class:`MetricsExporter` writes snapshot JSON to
  a path on a fixed cadence (``--metrics-json``).
"""
from __future__ import annotations

import json
import os
import random
import tempfile
import threading
from typing import Any, Callable, Dict, List, Optional

SCHEMA = "repro.serve.metrics/v2"

DEFAULT_RESERVOIR = 1024


class Counter:
    """Monotonic int counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value; ``set_max`` keeps the peak."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def add(self, delta: float) -> None:
        self.value += delta

    def set_max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Bounded uniform reservoir (Algorithm R).

    The first ``capacity`` samples are kept verbatim; sample ``i`` (>
    capacity) replaces a uniformly-chosen slot with probability
    ``capacity/i``. The RNG is seeded from the metric name, so two runs
    observing the same sample stream keep identical reservoirs —
    deterministic percentiles under the chaos suite's seeded plans.
    ``n``/``sum``/``min``/``max`` are exact over ALL samples regardless
    of capacity.
    """

    __slots__ = ("name", "capacity", "samples", "n", "sum",
                 "min", "max", "_rng")

    def __init__(self, name: str, capacity: int = DEFAULT_RESERVOIR):
        if capacity < 1:
            raise ValueError(f"reservoir capacity must be >= 1: {capacity}")
        self.name = name
        self.capacity = capacity
        self.samples: List[float] = []
        self.n = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._rng = random.Random(name)

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self.samples) < self.capacity:
            self.samples.append(v)
        else:
            j = self._rng.randrange(self.n)
            if j < self.capacity:
                self.samples[j] = v

    def percentile(self, q: float) -> float:
        """Linear-interpolated percentile of the reservoir; 0.0 when no
        samples have been observed (``n == 0`` disambiguates a true 0ms
        from "no data" — the old ``_pcts`` helper conflated them)."""
        s = sorted(self.samples)
        if not s:
            return 0.0
        if len(s) == 1:
            return s[0]
        pos = (q / 100.0) * (len(s) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(s) - 1)
        return s[lo] + (s[hi] - s[lo]) * (pos - lo)

    def summary(self, scale: float = 1.0, round_to: int = 3
                ) -> Dict[str, Any]:
        """The stable summary shape: p50/p95/mean (scaled, e.g. 1e3 for
        s→ms) plus exact n. Safe on 0 and 1 samples."""
        if self.n == 0:
            return {"p50": 0.0, "p95": 0.0, "mean": 0.0, "n": 0,
                    "min": 0.0, "max": 0.0}
        return {"p50": round(self.percentile(50) * scale, round_to),
                "p95": round(self.percentile(95) * scale, round_to),
                "mean": round(self.sum / self.n * scale, round_to),
                "n": self.n,
                "min": round(self.min * scale, round_to),
                "max": round(self.max * scale, round_to)}


class MetricsRegistry:
    """Get-or-create home for every metric; one versioned snapshot out."""

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR):
        self.reservoir = reservoir
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  capacity: Optional[int] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(
                name, capacity or self.reservoir)
        return h

    def snapshot(self, extra: Optional[Dict] = None,
                 hist_scales: Optional[Dict[str, float]] = None
                 ) -> Dict[str, Any]:
        """The versioned snapshot: every counter/gauge value and every
        histogram summary, JSON-serializable as-is. ``hist_scales`` maps
        histogram name → multiplier applied in its summary (seconds
        histograms export as ms). ``extra`` merges additional top-level
        sections (e.g. the deprecated legacy aliases)."""
        scales = hist_scales or {}
        out: Dict[str, Any] = {
            "schema": SCHEMA,
            "counters": {n: c.value for n, c in sorted(
                self.counters.items())},
            "gauges": {n: g.value for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary(scale=scales.get(n, 1.0))
                           for n, h in sorted(self.histograms.items())},
        }
        if extra:
            out.update(extra)
        return out


# ---------------------------------------------------------------------------
# Exposition
# ---------------------------------------------------------------------------
def _prom_name(name: str) -> str:
    out = "".join(ch if ch.isalnum() or ch == "_" else "_" for ch in name)
    return out if not out[:1].isdigit() else "_" + out


def prometheus_text(snapshot: Dict[str, Any], prefix: str = "repro",
                    labels: Optional[Dict[str, str]] = None) -> str:
    """Render a (v2) snapshot in the Prometheus text exposition format:
    counters/gauges verbatim, histogram summaries as
    ``<name>{quantile=...}`` plus ``_sum``-less ``_count``/``_mean``
    series. Works on any snapshot dict — including one replica's from a
    router — so the server can merge several registries."""
    lab = dict(labels or {})

    def fmt(extra: Optional[Dict[str, str]] = None) -> str:
        items = {**lab, **(extra or {})}
        if not items:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in sorted(items.items()))
        return "{" + inner + "}"

    lines: List[str] = []
    for name, v in snapshot.get("counters", {}).items():
        m = f"{prefix}_{_prom_name(name)}_total"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m}{fmt()} {v}")
    for name, v in snapshot.get("gauges", {}).items():
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m}{fmt()} {v}")
    for name, s in snapshot.get("histograms", {}).items():
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} summary")
        lines.append(f'{m}{fmt({"quantile": "0.5"})} {s["p50"]}')
        lines.append(f'{m}{fmt({"quantile": "0.95"})} {s["p95"]}')
        lines.append(f"{m}_count{fmt()} {s['n']}")
        lines.append(f"{m}_mean{fmt()} {s['mean']}")
    return "\n".join(lines) + "\n"


class MetricsExporter:
    """Daemon thread writing snapshot JSON to a path every
    ``interval_s`` (atomic replace), plus once on ``stop()`` so short
    runs still leave a final snapshot. ``supplier`` returns the object
    to serialize — one registry snapshot, or a merged multi-replica
    shape; the exporter doesn't care."""

    def __init__(self, path: str, supplier: Callable[[], Any],
                 interval_s: float = 1.0):
        self.path = path
        self.supplier = supplier
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def write_once(self) -> str:
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(self.supplier(), f, indent=1)
        os.replace(tmp, self.path)
        return self.path

    def start(self) -> "MetricsExporter":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="metrics-exporter", daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.write_once()
            except Exception:
                # a racing engine thread can mutate mid-snapshot; the
                # next tick writes a clean one — never kill the cadence
                pass

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self.write_once()


class MetricsServer:
    """Minimal Prometheus scrape endpoint: ``GET /metrics`` returns
    ``supplier()`` rendered through :func:`prometheus_text` per replica.
    ``supplier`` returns a list of snapshot dicts (one per replica —
    labeled ``replica="i"``). ``port=0`` binds an ephemeral port
    (``.port`` reports the bound one — tests use this)."""

    def __init__(self, supplier: Callable[[], List[Dict]], port: int = 0,
                 host: str = "127.0.0.1"):
        import http.server

        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):                      # noqa: N802 (stdlib API)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                snaps = outer.supplier()
                body = "".join(
                    prometheus_text(s, labels={"replica": str(i)})
                    for i, s in enumerate(snaps)).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):              # silence per-request spam
                pass

        self.supplier = supplier
        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "MetricsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="metrics-server",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
