"""Unified observability for the compression→serve pipeline
(DESIGN.md §6): tracing spans (``obs.trace``), the typed metrics
registry (``obs.metrics``) and the flight recorder (``obs.flightrec``).

The three share one philosophy: **near-zero cost when off, one schema
when on**. Tracing is a module-global switch — every ``span(...)`` call
sites throughout ``core/`` and ``serve/`` collapse to a shared no-op
singleton until a tracer is installed. Metrics are always on (bounded:
counters and fixed-size reservoirs, never per-request lists). The
flight recorder is always on too (a ring buffer) but only writes an
artifact when something goes wrong and a dump directory is configured.
"""
from repro.obs import flightrec, metrics, trace  # noqa: F401

__all__ = ["trace", "metrics", "flightrec"]
