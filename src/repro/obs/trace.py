"""Lightweight tracing spans emitting Chrome-trace / Perfetto JSON
(DESIGN.md §6.1).

The whole pipeline is instrumented with ``span("name", key=value)``
context managers — calibrate capture/fold, compress decompose buckets,
AOT warm/compile/deserialize, admission→prefill→decode per engine step,
elastic rung transitions. The contract that makes it safe to leave the
call sites in hot loops:

* **disabled is the default and costs one global read** — ``span()``
  returns a shared module-level no-op singleton when no tracer is
  installed: no object allocation, no timestamp, no lock
  (tests assert the singleton identity).
* **enabled is append-only under a lock** — events are plain dicts in
  insertion order with a monotonic sequence number, so a single engine
  thread produces a *deterministic* event order (asserted under a
  seeded ``FaultPlan``); concurrent client threads interleave safely.
* **the export is standard** — ``Tracer.to_chrome()`` emits the Chrome
  trace-event format (``{"traceEvents": [...]}`` with ``X`` complete
  spans, ``i`` instants, ``C`` counters, ``b``/``e`` async request
  spans, ``M`` thread names) that chrome://tracing and
  https://ui.perfetto.dev load directly.

Usage::

    from repro.obs import trace
    with trace.tracing(out="runs/serve.trace.json"):
        with trace.span("decode_step", step=i):
            ...
    # or explicitly: t = trace.enable(); ...; trace.disable().write(path)

Device-level capture: :func:`device_trace` wraps ``jax.profiler``
start/stop around a block when a log dir is given (the profiler's
TensorBoard/Perfetto artifacts land there); it is a no-op otherwise.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional

SCHEMA = "repro.trace/v1"

# Chrome trace event phases used here (the subset Perfetto renders):
# X complete span, i instant, C counter, b/e async begin/end, M metadata.


class _NullSpan:
    """Shared do-nothing span: the disabled-mode fast path. A single
    module-level instance is returned by every ``span()`` call while
    tracing is off, so a disabled call allocates no span object."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One live ``X`` (complete) event: enter stamps ``ts``, exit stamps
    ``dur`` and appends the finished event to the tracer."""

    __slots__ = ("_tracer", "_event", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Dict):
        self._tracer = tracer
        self._event = {"name": name, "ph": "X", "args": args}

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        ev = self._event
        tr = self._tracer
        ev["ts"] = (self._t0 - tr.epoch_ns) / 1e3     # Chrome wants µs
        ev["dur"] = (t1 - self._t0) / 1e3
        tr._append(ev)
        return False


class Tracer:
    """Thread-safe in-memory trace buffer with a Chrome-trace exporter.

    Events keep insertion order plus a monotonic ``seq`` (stable across
    identical runs on a single engine thread — wall-clock timestamps are
    attached but never used for ordering). ``max_events`` bounds memory;
    overflow drops the *newest* events and counts them, so a runaway
    loop can't OOM the process it is meant to debug.
    """

    def __init__(self, max_events: int = 1_000_000):
        self.epoch_ns = time.perf_counter_ns()
        self.max_events = max_events
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._seq = 0
        self._pid = os.getpid()
        self._named_tids: set = set()

    # ---- event sinks (called from any thread) ----------------------------
    def _append(self, ev: Dict[str, Any]) -> None:
        tid = threading.get_ident()
        with self._lock:
            if len(self.events) >= self.max_events:
                self.dropped += 1
                return
            ev["pid"] = self._pid
            ev["tid"] = tid
            ev["seq"] = self._seq
            self._seq += 1
            if tid not in self._named_tids:
                self._named_tids.add(tid)
                self.events.append(
                    {"name": "thread_name", "ph": "M", "pid": self._pid,
                     "tid": tid, "seq": -1,
                     "args": {"name": threading.current_thread().name}})
            self.events.append(ev)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        self._append({"name": name, "ph": "i", "s": "t",
                      "ts": self._now_us(), "args": args})

    def counter(self, name: str, **values) -> None:
        """A ``C`` event: Perfetto renders each kwarg as a counter track
        (used for queue depth and the elastic rung)."""
        self._append({"name": name, "ph": "C",
                      "ts": self._now_us(), "args": values})

    def async_begin(self, name: str, aid, **args) -> None:
        """Open an async span (``b``): lifetimes that cross engine steps,
        e.g. one serve request from admission to its terminal state."""
        self._append({"name": name, "ph": "b", "cat": name, "id": aid,
                      "ts": self._now_us(), "args": args})

    def async_end(self, name: str, aid, **args) -> None:
        self._append({"name": name, "ph": "e", "cat": name, "id": aid,
                      "ts": self._now_us(), "args": args})

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self.epoch_ns) / 1e3

    # ---- export ----------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (loadable by Perfetto)."""
        with self._lock:
            events = [dict(ev) for ev in self.events]
        return {"traceEvents": events,
                "displayTimeUnit": "ms",
                "otherData": {"schema": SCHEMA,
                              "dropped_events": self.dropped}}

    def write(self, path: str) -> str:
        """Atomically write the Chrome-trace JSON to ``path``."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(self.to_chrome(), f)
        os.replace(tmp, path)
        return path


# ---------------------------------------------------------------------------
# Module-global switch
# ---------------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def enable(tracer: Optional[Tracer] = None) -> Tracer:
    """Install ``tracer`` (or a fresh one) as the global trace sink."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def disable() -> Optional[Tracer]:
    """Remove the global tracer and return it (for export)."""
    global _tracer
    t, _tracer = _tracer, None
    return t


def current() -> Optional[Tracer]:
    return _tracer


def enabled() -> bool:
    return _tracer is not None


def span(name: str, **args):
    """A timed span context manager, or the shared no-op singleton when
    tracing is disabled (the hot-loop fast path)."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, **args)


def instant(name: str, **args) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, **args)


def counter(name: str, **values) -> None:
    t = _tracer
    if t is not None:
        t.counter(name, **values)


def async_begin(name: str, aid, **args) -> None:
    t = _tracer
    if t is not None:
        t.async_begin(name, aid, **args)


def async_end(name: str, aid, **args) -> None:
    t = _tracer
    if t is not None:
        t.async_end(name, aid, **args)


@contextlib.contextmanager
def tracing(out: Optional[str] = None, tracer: Optional[Tracer] = None):
    """Enable tracing for a block; on exit restore the previous tracer
    and (with ``out``) write the Chrome-trace JSON there."""
    global _tracer
    prev = _tracer
    t = enable(tracer)
    try:
        yield t
    finally:
        _tracer = prev
        if out:
            t.write(out)


@contextlib.contextmanager
def device_trace(logdir: Optional[str]):
    """Optional device-level capture: wraps ``jax.profiler``
    start/stop_trace around the block when ``logdir`` is set (XLA/TPU
    timelines land there, viewable in TensorBoard or Perfetto); a no-op
    when ``logdir`` is falsy or the profiler is unavailable."""
    if not logdir:
        yield None
        return
    try:
        import jax
        jax.profiler.start_trace(logdir)
        started = True
    except Exception:           # headless jaxlib without profiler support
        started = False
    try:
        yield logdir if started else None
    finally:
        if started:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass


# ---------------------------------------------------------------------------
# Schema check (shared by tests and the CI chaos drill)
# ---------------------------------------------------------------------------
_PHASES = {"X", "i", "C", "M", "b", "e"}


def validate_chrome_trace(obj: Dict) -> List[str]:
    """Validate a Chrome-trace JSON object; returns a list of problems
    (empty = valid). Checks exactly what Perfetto needs to load the
    file: a ``traceEvents`` list whose members carry name/ph/pid/tid,
    known phases, µs timestamps, non-negative durations on ``X`` spans
    and ids on async events."""
    errs: List[str] = []
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(evs):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            errs.append(f"{where}: bad name {ev.get('name')!r}")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errs.append(f"{where}: unknown phase {ph!r}")
            continue
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errs.append(f"{where}: {k} not an int")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"{where}: ts missing")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X span with bad dur {dur!r}")
        if ph in ("b", "e") and "id" not in ev:
            errs.append(f"{where}: async event without id")
    return errs
