"""Trainer: jitted step + deterministic sharded data + async checkpoints +
SIGTERM-safe shutdown + resume. The fault-tolerance posture (DESIGN.md §5):

  * checkpoint every `ckpt_every` steps on a worker thread (the train loop
    never blocks on disk);
  * SIGTERM/SIGINT triggers one final synchronous checkpoint before exit
    (preemption-safe on managed clusters);
  * restart resumes from LATEST — and because the data pipeline is
    counter-based in (seed, step, global_row), a restart on a *different*
    data-parallel topology replays the exact same global batches (elastic);
  * a heartbeat file (repro.dist.ft) lets an external supervisor detect
    stalled workers and reschedule — deterministic data means the
    replacement worker recomputes identical shards.
"""
from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import store
from repro.config import ModelConfig
from repro.data.synthetic import DataConfig, ShardedLoader
from repro.dist.ft import Heartbeat
from repro.train import step as TS


@dataclass
class LoopConfig:
    total_steps: int = 1000
    ckpt_dir: str = ""
    ckpt_every: int = 200
    log_every: int = 20
    keep_last: int = 3
    shard_id: int = 0
    num_shards: int = 1
    heartbeat_path: str = ""


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TS.TrainConfig,
                 dcfg: DataConfig, lcfg: LoopConfig,
                 seed: int = 0):
        self.cfg, self.tcfg, self.dcfg, self.lcfg = cfg, tcfg, dcfg, lcfg
        self.loader = ShardedLoader(dcfg, lcfg.shard_id, lcfg.num_shards)
        self.state, self.specs = TS.init_train_state(
            cfg, jax.random.PRNGKey(seed))
        self.step_fn = jax.jit(TS.make_train_step(cfg, tcfg),
                               donate_argnums=0)
        self.start_step = 0
        self.history: List[Dict] = []
        self._stop = False
        self._ckpt: Optional[store.AsyncCheckpointer] = None
        self._hb = (Heartbeat(lcfg.heartbeat_path)
                    if lcfg.heartbeat_path else None)
        if lcfg.ckpt_dir:
            os.makedirs(lcfg.ckpt_dir, exist_ok=True)
            if store.latest_step(lcfg.ckpt_dir) is not None:
                s, self.state = store.restore(lcfg.ckpt_dir, self.state)
                self.start_step = s
            self._ckpt = store.AsyncCheckpointer(lcfg.ckpt_dir,
                                                 lcfg.keep_last)

    # -- signals ------------------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass        # non-main thread (tests)

    def run(self) -> Dict:
        self._install_signals()
        lcfg = self.lcfg
        t0 = time.time()
        s = self.start_step
        while s < lcfg.total_steps and not self._stop:
            batch = {k: jnp.asarray(v)
                     for k, v in self.loader.batch(s).items()}
            self.state, m = self.step_fn(self.state, batch)
            s += 1
            if self._hb:
                self._hb.beat(s)
            if s % lcfg.log_every == 0 or s == lcfg.total_steps:
                row = {k: float(v) for k, v in m.items()}
                row["step"] = s
                row["wall_s"] = time.time() - t0
                self.history.append(row)
            if self._ckpt and s % lcfg.ckpt_every == 0:
                self._ckpt.submit(s, self.state, {"loss": float(m["loss"])})
        # final checkpoint: synchronous (covers SIGTERM preemption)
        if lcfg.ckpt_dir:
            if self._ckpt:
                self._ckpt.close()
            store.save(lcfg.ckpt_dir, s, self.state,
                       {"final": True, "interrupted": self._stop},
                       keep_last=lcfg.keep_last)
        if self._hb:
            self._hb.close()
        return {"final_step": s, "interrupted": self._stop,
                "history": self.history}
