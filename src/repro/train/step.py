"""Train step: microbatched gradient accumulation, bf16 compute / fp32
optimizer state, remat per block (inside the model's layer scan), AdamW.

The same `train_step` lowers on one CPU device (tests) and on the
production meshes (dry-run / deploy): sharding comes entirely from the
in/out shardings the launcher attaches (logical rules in
``repro.dist.sharding``), never from the step itself.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.models.params import Params
from repro.optim.adamw import (AdamWState, OptimizerConfig, adamw_init,
                               adamw_update)


class TrainState(NamedTuple):
    params: Params
    opt: AdamWState


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1         # grad accumulation steps per train step
    optimizer: OptimizerConfig = OptimizerConfig()


def init_train_state(cfg: ModelConfig, key: jax.Array
                     ) -> Tuple[TrainState, Params]:
    params, specs = T.init_model(cfg, key)
    return TrainState(params=params, opt=adamw_init(params)), specs


def _microbatch(batch: Dict, n: int, i) -> Dict:
    """Slice microbatch i of n along the batch dim."""
    def sl(v):
        mb = v.shape[0] // n if v.ndim >= 2 and v.shape[0] >= n else None
        if mb is None:
            return v
        return jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=0)
    out = {}
    for k, v in batch.items():
        if k.startswith("enc_") or k == "positions":
            # positions may carry a leading component axis (m-rope: (3,B,S))
            if k == "positions" and v.ndim == 3:
                mb = v.shape[1] // n
                out[k] = jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=1)
                continue
        out[k] = sl(v)
    return out


def loss_and_grads(params: Params, cfg: ModelConfig, batch: Dict,
                   microbatches: int = 1):
    """Microbatched value_and_grad: the loop is a lax.scan so logits of only
    one microbatch are ever live (vocab-sharded CE peaks at B/n · S · V)."""
    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            T.lm_loss, has_aux=True)(params, cfg, batch)
        return loss, metrics, grads

    def body(carry, i):
        acc_loss, acc_grads, acc_metrics = carry
        mb = _microbatch(batch, microbatches, i)
        (loss, metrics), grads = jax.value_and_grad(
            T.lm_loss, has_aux=True)(params, cfg, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                           acc_grads, grads)
        mkeys = ("loss", "accuracy", "tokens")
        new_metrics = {k: acc_metrics[k] + metrics[k] for k in mkeys}
        return (acc_loss + loss, acc, new_metrics), None

    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zero_m = {"loss": jnp.zeros(()), "accuracy": jnp.zeros(()),
              "tokens": jnp.zeros(())}
    (loss, grads, metrics), _ = jax.lax.scan(
        body, (jnp.zeros(()), zero_g, zero_m),
        jnp.arange(microbatches))
    n = float(microbatches)
    grads = jax.tree.map(lambda g: g / n, grads)
    metrics = {k: v / n for k, v in metrics.items()}
    metrics["tokens"] = metrics["tokens"] * n
    return loss / n, metrics, grads


def train_step(state: TrainState, batch: Dict, *, cfg: ModelConfig,
               tcfg: TrainConfig) -> Tuple[TrainState, Dict]:
    loss, metrics, grads = loss_and_grads(state.params, cfg, batch,
                                          tcfg.microbatches)
    new_params, new_opt, stats = adamw_update(
        tcfg.optimizer, grads, state.opt, state.params)
    metrics = dict(metrics)
    metrics.update(stats)
    return TrainState(params=new_params, opt=new_opt), metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    return functools.partial(train_step, cfg=cfg, tcfg=tcfg)


# ---------------------------------------------------------------------------
# Eval
# ---------------------------------------------------------------------------
def eval_step(params: Params, cfg: ModelConfig, batch: Dict) -> Dict:
    _, metrics = T.lm_loss(params, cfg, batch)
    return metrics


def evaluate_ppl(params: Params, cfg: ModelConfig, batches) -> Dict:
    """Token-weighted perplexity over an iterable of batches."""
    tot_nll, tot_tok, tot_acc = 0.0, 0.0, 0.0
    for b in batches:
        m = eval_step(params, cfg, b)
        tok = float(m["tokens"])
        tot_nll += float(m["loss"]) * tok
        tot_acc += float(m["accuracy"]) * tok
        tot_tok += tok
    import math
    nll = tot_nll / max(1.0, tot_tok)
    return {"nll": nll, "ppl": math.exp(min(nll, 30.0)),
            "accuracy": tot_acc / max(1.0, tot_tok)}
