"""LoRA fine-tuning on (compressed) models — the paper's Figure-3 recovery
path: D-Rank + LoRA beats baselines + LoRA at every ratio.

Adapters ride inside each linear's param dict ("lora_A"/"lora_B"/
"lora_scale", consumed by ``params.apply_linear``), so the same model code
serves dense, factorized, and adapted weights. Only adapter leaves get
gradients (the base tree is closed over, not differentiated)."""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.models.params import Params
from repro.optim.adamw import (OptimizerConfig, adamw_init,
                               adamw_update)

_LORA_TARGETS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"}


def _is_linear(d) -> bool:
    return isinstance(d, dict) and ("w" in d or ("B" in d and "C" in d))


def _dims(d) -> Tuple[int, int]:
    if "w" in d:
        return int(d["w"].shape[-2]), int(d["w"].shape[-1])
    return int(d["B"].shape[-2]), int(d["C"].shape[-1])


def init_lora(params: Params, cfg: ModelConfig, key: jax.Array,
              rank: int = 8, alpha: float = 32.0) -> Dict:
    """Returns a sparse adapter tree {joined-path: {"lora_A","lora_B",
    "lora_scale"}} over every target linear (stacked runs get a leading
    stack dim; list runs get per-layer entries)."""
    adapters: Dict[str, Dict] = {}
    n = [0]

    def walk(node, path):
        if _is_linear(node) and path and str(path[-1]) in _LORA_TARGETS:
            d_in, d_out = _dims(node)
            lead = ()
            w = node.get("w", node.get("B"))
            if w.ndim == 3:
                lead = (w.shape[0],)
            n[0] += 1
            k = jax.random.fold_in(key, n[0])
            adapters["/".join(map(str, path))] = {
                "lora_A": 0.01 * jax.random.normal(
                    k, (*lead, d_in, rank), dtype=jnp.float32),
                "lora_B": jnp.zeros((*lead, rank, d_out),
                                    dtype=jnp.float32),
                "lora_scale": jnp.asarray(alpha / rank, dtype=jnp.float32),
            }
            return
        if isinstance(node, dict):
            for kk, v in node.items():
                walk(v, path + (kk,))
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(v, path + (i,))

    walk(params, ())
    return adapters


def merge_lora(params: Params, adapters: Dict) -> Params:
    """Non-destructively insert adapter leaves into the param tree."""
    def get(tree, path):
        node = tree
        for kk in path:
            node = node[kk]
        return node

    out = jax.tree.map(lambda x: x, params)      # shallow-ish copy

    def copy_path(tree, path):
        # rebuild dicts/lists along the path so we never mutate the input
        node = tree
        for kk in path:
            child = node[kk]
            child = dict(child) if isinstance(child, dict) else list(child)
            node[kk] = child
            node = child
        return node

    for pth, ad in adapters.items():
        keys = [int(p) if p.isdigit() else p for p in pth.split("/")]
        out = out if isinstance(out, dict) else out
        node = copy_path(out, keys)
        node.update(ad)
    return out


def lora_finetune(params: Params, cfg: ModelConfig,
                  batches: Iterable[Dict], steps: int,
                  rank: int = 8, alpha: float = 32.0, lr: float = 1e-4,
                  seed: int = 0) -> Tuple[Params, List[Dict]]:
    """Fine-tune adapters only; returns (merged params, history)."""
    adapters = init_lora(params, cfg, jax.random.PRNGKey(seed), rank, alpha)
    ocfg = OptimizerConfig(lr=lr, warmup_steps=max(1, steps // 20),
                           total_steps=steps, weight_decay=0.0)
    opt = adamw_init(adapters)

    def loss_fn(ad, batch):
        merged = merge_lora(params, ad)
        return T.lm_loss(merged, cfg, batch)

    @jax.jit
    def step_fn(ad, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(ad, batch)
        ad2, opt2, stats = adamw_update(ocfg, grads, opt, ad)
        return ad2, opt2, {**metrics, **stats}

    history = []
    it = iter(batches)
    for s in range(steps):
        batch = next(it)
        adapters, opt, m = step_fn(adapters, opt, batch)
        if s % 20 == 0 or s == steps - 1:
            history.append({"step": s, "loss": float(m["loss"])})
    return merge_lora(params, adapters), history
