#!/usr/bin/env python
"""README command smoke: extract every fenced ``bash`` block from
README.md and execute it (bash -euo pipefail, repo root, PYTHONPATH=src),
so the walkthrough can never drift from the code. Blocks whose fence info
string contains ``no-check`` are skipped (e.g. the 10-minute tier-1
pytest command — CI runs it separately anyway).

    python scripts/check_readme.py [README.md]
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE = re.compile(r"^```(?P<info>[^\n]*)\n(?P<body>.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)


def blocks(text: str):
    for m in FENCE.finditer(text):
        info = m.group("info").strip().split()
        if not info or info[0] != "bash":
            continue
        if "no-check" in info:
            continue
        yield m.group("body")


def main(argv=None) -> int:
    path = (argv or sys.argv[1:] or ["README.md"])[0]
    with open(os.path.join(ROOT, path)) as f:
        todo = list(blocks(f.read()))
    if not todo:
        print(f"check_readme: no checkable bash blocks in {path}")
        return 1
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for i, body in enumerate(todo, 1):
        print(f"== README block {i}/{len(todo)} ==")
        print(body.rstrip())
        proc = subprocess.run(
            ["bash", "-euo", "pipefail", "-c", body], cwd=ROOT, env=env)
        if proc.returncode != 0:
            print(f"check_readme: block {i} FAILED "
                  f"(exit {proc.returncode})")
            return proc.returncode
    print(f"check_readme: {len(todo)} blocks ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
