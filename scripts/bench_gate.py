#!/usr/bin/env python
"""Bench regression gate: diff a freshly emitted BENCH_*.json against its
committed smoke baseline and fail on a throughput regression.

    python scripts/bench_gate.py BENCH_decode.json \
        benchmarks/baselines/BENCH_decode.smoke.json --threshold 0.25

Rows are matched by their full ``config`` dict. ``pallas-interpret`` rows
are skipped — interpreter wall-times are correctness evidence, not a perf
claim (DESIGN.md §3). Baselines were recorded on the repo's CI container;
the threshold is deliberately loose (25%) to absorb machine-to-machine
variance, and ``--update`` refreshes a baseline in place after an
intentional perf change.

``--metric`` selects the throughput field: decode/calib benches gate
``tokens_per_s``; the compression-math bench gates its tokens/s
equivalent ``params_per_s`` (dense parameters decomposed per second).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def _key(row):
    return json.dumps(row["config"], sort_keys=True)


def _skip(row) -> bool:
    return "interpret" in str(row["config"].get("path", ""))


def gate(current_path: str, baseline_path: str, threshold: float,
         metric: str = "tokens_per_s") -> int:
    with open(current_path) as f:
        current = {_key(r): r for r in json.load(f)}
    with open(baseline_path) as f:
        baseline = [r for r in json.load(f) if not _skip(r)]
    if not baseline:
        print(f"bench_gate: {baseline_path} has no gateable rows")
        return 1
    failures = []
    for ref in baseline:
        k = _key(ref)
        if k not in current:
            failures.append(f"  missing row {k}")
            continue
        got = current[k][metric]
        want = ref[metric]
        drop = 1.0 - got / want if want > 0 else 0.0
        status = "FAIL" if drop > threshold else "ok"
        print(f"  [{status}] {k}: {got:.0f} vs baseline {want:.0f} "
              f"({-drop:+.1%})")
        if drop > threshold:
            failures.append(
                f"  {k}: {metric} {got:.0f} < {want:.0f} "
                f"(-{drop:.1%} > allowed {threshold:.0%})")
    if failures:
        print(f"bench_gate: REGRESSION vs {baseline_path}:")
        print("\n".join(failures))
        return 1
    print(f"bench_gate: ok ({len(baseline)} rows within {threshold:.0%} "
          f"of {baseline_path})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max fractional metric drop (default 0.25)")
    ap.add_argument("--metric", default="tokens_per_s",
                    help="throughput field to diff "
                         "(default tokens_per_s)")
    ap.add_argument("--update", action="store_true",
                    help="copy current over the baseline instead of gating")
    args = ap.parse_args(argv)
    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"bench_gate: baseline {args.baseline} updated")
        return 0
    return gate(args.current, args.baseline, args.threshold, args.metric)


if __name__ == "__main__":
    sys.exit(main())
