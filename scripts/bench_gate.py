#!/usr/bin/env python
"""Bench regression gate: diff a freshly emitted BENCH_*.json against its
committed smoke baseline and fail on a throughput regression.

    python scripts/bench_gate.py BENCH_decode.json \
        benchmarks/baselines/BENCH_decode.smoke.json --threshold 0.25

Rows are matched by their full ``config`` dict. ``pallas-interpret`` rows
are skipped — interpreter wall-times are correctness evidence, not a perf
claim (DESIGN.md §3).

**Per-machine calibration** (ISSUE 6 / ROADMAP "normalize to a
calibration row"): baselines written with ``--update`` carry a
``_calibration`` row — the score of a fixed single-threaded numpy matmul
probe measured ON THE MACHINE THAT RECORDED THE BASELINE. At gate time
the probe runs again and every baseline metric is scaled by
``clamp(score_now / score_then, 1/3, 3)`` before the diff: a runner half
as fast as the recorder is expected to produce half the tokens/s, and no
longer needs a hand-tuned ``BENCH_GATE_THRESHOLD`` to pass. The clamp
bounds how much slack a wildly different machine can claim, so a real 10x
regression still fails everywhere. Baselines without the row gate
unscaled (scale 1), exactly as before.

``--metric`` selects the throughput field: decode/calib benches gate
``tokens_per_s``; the compression-math bench gates its tokens/s
equivalent ``params_per_s`` (dense parameters decomposed per second).
``--update`` refreshes a baseline in place (recording the calibration
row) after an intentional perf change.

**Ratio mode** (``--ratio NUM_SEL DEN_SEL --min-ratio 0.95``): instead
of diffing against a baseline, gate the metric ratio of two rows inside
the freshly emitted file itself, selected by ``config`` key=value pairs
— e.g. the tracing-overhead pair ``mode=trace-on`` vs
``mode=trace-off`` from one interleaved run, where the ratio is immune
to the machine-speed question entirely.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

CAL_BENCH = "_calibration"
CAL_PROBE = "numpy-matmul-256"
CAL_CLAMP = 3.0                 # max speed ratio honored either direction


def _key(row):
    return json.dumps(row["config"], sort_keys=True)


def _skip(row) -> bool:
    if row.get("bench") == CAL_BENCH:
        return True
    return "interpret" in str(row["config"].get("path", ""))


def measure_calibration(reps: int = 20, loops: int = 16) -> float:
    """Score of a fixed numpy workload (float64 256x256 matmul chain),
    best-of-``reps`` windows. Deterministic shape/content; the score is
    ~GFLOP/s of the BLAS this machine actually dispatches to — the same
    arithmetic the benches themselves lean on. Each window is ~10ms and
    only the best counts, so co-tenant scheduler noise has ``reps``
    chances to miss at least one window (single shots swung ~30% on the
    containers this gate runs in)."""
    import numpy as np
    n = 256
    a = np.arange(n * n, dtype=np.float64).reshape(n, n) / (n * n)
    b = a.T.copy()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        c = a
        for _ in range(loops):
            c = c @ b
        c.sum()                         # keep the chain alive
        best = min(best, time.perf_counter() - t0)
    flops = 2.0 * loops * n**3
    return flops / best / 1e9


def calibration_row(score: float) -> dict:
    return {"bench": CAL_BENCH, "config": {"probe": CAL_PROBE},
            "score": round(score, 3)}


def machine_scale(baseline_rows) -> tuple:
    """(scale, detail) — how much faster/slower this machine is than the
    one that recorded the baseline, clamped; (1.0, reason) when the
    baseline predates calibration rows."""
    ref = next((r for r in baseline_rows
                if r.get("bench") == CAL_BENCH
                and r.get("config", {}).get("probe") == CAL_PROBE), None)
    if ref is None or not ref.get("score"):
        return 1.0, "no calibration row in baseline (unscaled gate)"
    now = measure_calibration()
    raw = now / ref["score"]
    scale = max(1.0 / CAL_CLAMP, min(CAL_CLAMP, raw))
    detail = (f"machine probe {now:.1f} vs baseline {ref['score']:.1f} "
              f"GFLOP/s -> scale {scale:.2f}"
              + (" (clamped)" if scale != raw else ""))
    return scale, detail


def gate(current_path: str, baseline_path: str, threshold: float,
         metric: str = "tokens_per_s") -> int:
    with open(current_path) as f:
        current = {_key(r): r for r in json.load(f) if not _skip(r)}
    with open(baseline_path) as f:
        baseline_all = json.load(f)
    baseline = [r for r in baseline_all if not _skip(r)]
    if not baseline:
        print(f"bench_gate: {baseline_path} has no gateable rows")
        return 1
    scale, detail = machine_scale(baseline_all)
    print(f"bench_gate: {detail}")
    failures = []
    for ref in baseline:
        k = _key(ref)
        if k not in current:
            failures.append(f"  missing row {k}")
            continue
        got = current[k][metric]
        want = ref[metric] * scale
        drop = 1.0 - got / want if want > 0 else 0.0
        status = "FAIL" if drop > threshold else "ok"
        print(f"  [{status}] {k}: {got:.0f} vs scaled baseline {want:.0f} "
              f"({-drop:+.1%})")
        if drop > threshold:
            failures.append(
                f"  {k}: {metric} {got:.0f} < {want:.0f} "
                f"(-{drop:.1%} > allowed {threshold:.0%})")
    if failures:
        print(f"bench_gate: REGRESSION vs {baseline_path}:")
        print("\n".join(failures))
        return 1
    print(f"bench_gate: ok ({len(baseline)} rows within {threshold:.0%} "
          f"of {baseline_path})")
    return 0


def _select(rows, selector: str):
    """The single row whose config matches every ``key=value`` pair in
    ``selector`` (comma-separated; values compared as strings)."""
    pairs = [kv.split("=", 1) for kv in selector.split(",")]
    hits = [r for r in rows
            if all(str(r.get("config", {}).get(k)) == v for k, v in pairs)]
    if len(hits) != 1:
        raise SystemExit(f"bench_gate: selector {selector!r} matched "
                         f"{len(hits)} rows (want exactly 1)")
    return hits[0]


def ratio_gate(current_path: str, num_sel: str, den_sel: str,
               min_ratio: float, metric: str = "tokens_per_s") -> int:
    """Gate the metric ratio of two rows in the SAME freshly emitted
    file — e.g. tracing-on vs tracing-off throughput. Both rows come
    from one interleaved run on one machine, so no baseline file and no
    machine calibration is involved: the ratio is the claim."""
    with open(current_path) as f:
        rows = json.load(f)
    num = _select(rows, num_sel)
    den = _select(rows, den_sel)
    ratio = num[metric] / den[metric] if den[metric] > 0 else float("inf")
    ok = ratio >= min_ratio
    print(f"bench_gate: {metric} ratio [{num_sel}] / [{den_sel}] = "
          f"{num[metric]:.0f} / {den[metric]:.0f} = {ratio:.3f} "
          f"({'ok' if ok else 'FAIL'}, floor {min_ratio})")
    return 0 if ok else 1


def update(current_path: str, baseline_path: str) -> int:
    """Refresh the baseline from current rows + a calibration row scored
    on THIS machine (so future gates on other machines normalize to it)."""
    with open(current_path) as f:
        rows = [r for r in json.load(f) if r.get("bench") != CAL_BENCH]
    rows.append(calibration_row(measure_calibration()))
    with open(baseline_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"bench_gate: baseline {baseline_path} updated "
          f"({rows[-1]['score']} GFLOP/s calibration row recorded)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline", nargs="?", default=None)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max fractional metric drop (default 0.25)")
    ap.add_argument("--metric", default="tokens_per_s",
                    help="throughput field to diff "
                         "(default tokens_per_s)")
    ap.add_argument("--update", action="store_true",
                    help="refresh the baseline from current (records a "
                         "per-machine calibration row) instead of gating")
    ap.add_argument("--ratio", nargs=2, metavar=("NUM_SEL", "DEN_SEL"),
                    help="gate the metric ratio of two rows inside "
                         "CURRENT (selected by config key=value[,k=v]) "
                         "instead of diffing against a baseline — e.g. "
                         "--ratio mode=trace-on mode=trace-off")
    ap.add_argument("--min-ratio", type=float, default=0.95,
                    help="with --ratio: minimum num/den metric ratio "
                         "(default 0.95)")
    args = ap.parse_args(argv)
    if args.ratio:
        return ratio_gate(args.current, args.ratio[0], args.ratio[1],
                          args.min_ratio, args.metric)
    if args.baseline is None:
        ap.error("baseline is required unless --ratio is given")
    if args.update:
        return update(args.current, args.baseline)
    return gate(args.current, args.baseline, args.threshold, args.metric)


if __name__ == "__main__":
    sys.exit(main())
