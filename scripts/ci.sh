#!/usr/bin/env bash
# CI gate: tier-1 tests + decode-path benchmark smoke (interpret-mode
# Pallas — this runner has no TPU). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== decode-path benchmark smoke =="
python -m benchmarks.fig4_decode_path --smoke --force

echo "== BENCH_decode.json =="
python - <<'EOF'
import json
rows = json.load(open("BENCH_decode.json"))
assert rows, "no benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_step"} <= set(r), r
models = {r["config"]["model"] for r in rows}
assert "dense" in models and len(models) > 1, models
print(f"ok: {len(rows)} rows, models={sorted(models)}")
EOF
echo "CI OK"
