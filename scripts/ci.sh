#!/usr/bin/env bash
# CI gate: lint (if ruff is installed) + fast-lane tests + benchmark smokes
# (interpret-mode Pallas — CI runners have no TPU) + bench regression gate
# against committed baselines. Run from anywhere.
#
# The fast lane runs `-m "not slow"`; the tier-1 full suite (ROADMAP.md)
# is plain `pytest -q` and still covers the slow-marked sweeps.
# Set BENCH_GATE=off to skip the regression diff (e.g. exotic hardware).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# One persistent XLA compilation cache for every step in this script (and,
# via the workflow's cache action, across CI runs): each jit program is
# compiled once, then replayed. The boot-TTFT bench strips this variable
# from its child cells — its cold/warm boots must stay honest.
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$PWD/.cache/jax}"
mkdir -p "$JAX_COMPILATION_CACHE_DIR"

if command -v ruff >/dev/null 2>&1; then
  echo "== lint (ruff) =="
  ruff check .
else
  echo "== lint skipped (ruff not installed; the CI lint job enforces it) =="
fi

echo "== fast-lane tests (-m 'not slow') =="
python -m pytest -x -q -m "not slow"

echo "== public-API doctests =="
python -m pytest -q --doctest-modules \
  src/repro/core/compress.py src/repro/core/capture.py \
  src/repro/serve/engine.py src/repro/serve/api.py

echo "== README command smoke =="
python scripts/check_readme.py

echo "== observability chaos drill (traced poison + flight dump) =="
# A seed-deterministic poisoned request must fail typed while the run
# still drains; the trace must be valid Chrome-trace JSON (uploaded as a
# workflow artifact) and the flight recorder must dump an artifact that
# identifies the poisoned rid and the rung it failed at — from the dump
# alone, no logs.
rm -rf runs/ci_chaos && mkdir -p runs/ci_chaos
python -m repro.launch.serve --arch llama-mini \
  --requests 4 --n-new 4 --prompt-len 4 --batch 2 --max-len 64 \
  --fault-plan '{"seed": 3, "poison_rids": [2]}' --max-retries 1 \
  --trace-out runs/ci_chaos/trace.json \
  --metrics-json runs/ci_chaos/metrics.json \
  --flightrec-dir runs/ci_chaos
python - <<'EOF'
import glob
import json

from repro.obs.flightrec import validate_dump
from repro.obs.trace import validate_chrome_trace

trace = json.load(open("runs/ci_chaos/trace.json"))
errs = validate_chrome_trace(trace)
assert errs == [], errs
names = {e["name"] for e in trace["traceEvents"]}
assert {"engine_step", "decode_step", "prefill",
        "request"} <= names, sorted(names)

dumps = sorted(glob.glob("runs/ci_chaos/flightrec-*.json"))
assert dumps, "poison failure produced no flight-recorder dump"
dump = json.load(open(dumps[0]))
errs = validate_dump(dump)
assert errs == [], errs
assert dump["reason"] == "failed_poison", dump["reason"]
assert dump["context"]["rid"] == 2, dump["context"]
assert dump["context"]["fault_plan"]["poison_rids"] == [2]
assert any(ev["kind"] == "poison" and 2 in ev["rids"]
           for ev in dump["events"]), "no poison event in the ring"

snap = json.load(open("runs/ci_chaos/metrics.json"))
assert snap["schema"] == "repro.serve.metrics/v2", snap.get("schema")
assert snap["counters"]["poison_failures"] == 1, snap["counters"]
print(f"ok: chaos drill — {len(trace['traceEvents'])} trace events, "
      f"dump {dumps[0]} names rid=2 at rung "
      f"{dump['context']['rank_level']}")
EOF

echo "== decode-path benchmark smoke =="
python -m benchmarks.fig4_decode_path --smoke --force

echo "== calibration-capture benchmark smoke =="
python -m benchmarks.calib_capture --smoke --force

echo "== compression-math benchmark smoke =="
python -m benchmarks.compress_path --smoke --force

echo "== sharded-calibration benchmark smoke (8-device host mesh) =="
# --force even though the README smoke above usually just ran this bench:
# relying on that cross-file coincidence would let an edited README leave
# a stale cache re-emitting numbers the current commit never produced
python -m benchmarks.calib_sharded --smoke --force

echo "== serve-degradation benchmark smoke (elastic-rank ladder) =="
python -m benchmarks.serve_degrade --smoke --force

echo "== boot-TTFT benchmark smoke (AOT front door) =="
python -m benchmarks.boot_ttft --smoke --force

echo "== paged-KV benchmark smoke (block-table pool + prefix reuse) =="
python -m benchmarks.serve_paged --smoke --force

echo "== BENCH json schemas =="
python - <<'EOF'
import json
rows = json.load(open("BENCH_decode.json"))
assert rows, "no decode benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_step"} <= set(r), r
models = {r["config"]["model"] for r in rows}
assert "dense" in models and len(models) > 1, models
print(f"ok: BENCH_decode.json {len(rows)} rows, models={sorted(models)}")

rows = json.load(open("BENCH_calib.json"))
assert rows, "no calib benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_batch"} <= set(r), r
paths = {r["config"]["path"] for r in rows}
assert {"eager-host", "jit-device", "pallas-interpret"} <= paths, paths
err = max(r.get("max_rel_err", 0.0) for r in rows)
assert err < 1e-4, f"streaming capture parity broke: {err}"
print(f"ok: BENCH_calib.json {len(rows)} rows, paths={sorted(paths)}, "
      f"max_rel_err={err:.1e}")

rows = json.load(open("BENCH_calib_sharded.json"))
assert rows, "no sharded-calib benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_batch",
            "max_rel_err"} <= set(r), r
paths = {r["config"]["path"] for r in rows}
assert {"mesh-replicated", "mesh-sharded", "mesh-whiten"} <= paths, paths
assert all(r["config"]["devices"] == 8 for r in rows), rows
err = max(r["max_rel_err"] for r in rows)
assert err < 1e-4, f"mesh capture parity broke: {err}"
print(f"ok: BENCH_calib_sharded.json {len(rows)} rows, "
      f"paths={sorted(paths)}, max_rel_err={err:.1e}")

rows = json.load(open("BENCH_compress.json"))
assert rows, "no compress benchmark rows"
for r in rows:
    assert {"bench", "config", "params_per_s", "ms_per_group"} <= set(r), r
paths = {r["config"]["path"] for r in rows}
assert {"host-eager", "jit-device", "randomized"} <= paths, paths
exact_err = max(r["max_rel_err"] for r in rows
                if r["config"]["path"] == "jit-device")
assert exact_err < 1e-3, f"device compression math diverged: {exact_err}"
# the committed baseline records >=10x on a quiet runner; at CI time only
# assert a loose floor so scheduler noise can't flake the lane — and only
# when perf gating is on at all (BENCH_GATE=off covers exotic hardware)
import os
speedups = [r["speedup"] for r in rows
            if r["config"]["path"] == "jit-device" and "speedup" in r]
if os.environ.get("BENCH_GATE", "on") != "off":
    assert speedups and max(speedups) >= 5.0, \
        f"jit-device compression speedup collapsed: {speedups}"
top = max(speedups) if speedups else float("nan")
print(f"ok: BENCH_compress.json {len(rows)} rows, paths={sorted(paths)}, "
      f"exact_err={exact_err:.1e}, speedup={top:.1f}x")

rows = json.load(open("BENCH_serve_degrade.json"))
assert rows, "no serve-degrade benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_step",
            "ttft_p50_ms"} <= set(r), r
pinned = {r["config"]["level"]: r for r in rows
          if r["config"]["mode"] == "pinned"}
assert set(pinned) >= {0, 1, 2}, sorted(pinned)
# rank must genuinely drop down the ladder (pow2 buckets, ISSUE 6)
rmax = [pinned[lv]["rank_max"] for lv in sorted(pinned)]
assert rmax == sorted(rmax, reverse=True) and rmax[-1] < rmax[0], rmax
elastic = [r for r in rows if r["config"]["mode"] == "elastic"]
assert elastic and elastic[0]["rank_residency"], elastic
# the tracing-overhead pair (ISSUE 8) must be present; the ratio
# itself is perf and is gated below only when BENCH_GATE is on
tr = {r["config"]["mode"] for r in rows
      if str(r["config"]["mode"]).startswith("trace-")}
assert tr == {"trace-off", "trace-on"}, sorted(tr)
print(f"ok: BENCH_serve_degrade.json {len(rows)} rows, "
      f"rank ladder {rmax}, elastic residency "
      f"{elastic[0]['rank_residency']}")

rows = json.load(open("BENCH_boot.json"))
assert rows, "no boot benchmark rows"
for r in rows:
    assert {"bench", "config", "ttft_s", "boots_per_s",
            "aot_compiles", "aot_cache_hits"} <= set(r), r
cells = {r["config"]["mode"]: r for r in rows}
assert {"traced", "aot_cold", "aot_warm"} <= set(cells), sorted(cells)
warm = cells["aot_warm"]
# the AOT contract, not a perf claim: a warm boot never compiles
assert warm["aot_compiles"] == 0 and warm["aot_cache_hits"] > 0, warm
# the acceptance bar (ISSUE 7): warm-AOT first token >=5x faster than the
# tracing boot — perf, so honored only when perf gating is on at all
if os.environ.get("BENCH_GATE", "on") != "off":
    assert warm.get("speedup_vs_traced", 0.0) >= 5.0, warm
print(f"ok: BENCH_boot.json {len(rows)} rows, warm-AOT "
      f"{warm['ttft_s']}s to first token "
      f"({warm.get('speedup_vs_traced', float('nan'))}x vs traced)")

rows = json.load(open("BENCH_serve_paged.json"))
assert rows, "no paged-KV benchmark rows"
for r in rows:
    assert {"bench", "config", "tokens_per_s", "ms_per_step",
            "peak_kv_mib"} <= set(r), r
cells = {r["config"]["mode"]: r for r in rows}
assert set(cells) == {"contiguous", "paged", "paged+prefix"}, sorted(cells)
# the memory claim, not a perf claim: the paged pool's peak block
# footprint stays below the contiguous pool's full allocation
contig = cells["contiguous"]["peak_kv_mib"]
for mode in ("paged", "paged+prefix"):
    assert cells[mode]["peak_kv_mib"] < contig, (mode, cells[mode], contig)
# prefix reuse must actually fire on the shared-header group
assert cells["paged+prefix"]["prefix_hits"] > 0, cells["paged+prefix"]
print(f"ok: BENCH_serve_paged.json {len(rows)} rows, peak KV "
      f"{contig:.2f} -> {cells['paged']['peak_kv_mib']:.2f} MiB, "
      f"prefix_hits={cells['paged+prefix']['prefix_hits']}")
EOF

# Baselines carry a per-machine _calibration row (scripts/bench_gate.py
# --update): at gate time a fixed numpy probe rescales the recorded
# tokens/s to THIS runner's speed (clamped 3x), so a slower machine no
# longer needs BENCH_GATE_THRESHOLD loosened by hand. The threshold now
# only absorbs run-to-run noise; BENCH_GATE=off still skips entirely.
if [ "${BENCH_GATE:-on}" != "off" ]; then
  THRESH="${BENCH_GATE_THRESHOLD:-0.25}"
  echo "== bench regression gate (>${THRESH} scaled tokens/s drop fails) =="
  python scripts/bench_gate.py BENCH_decode.json \
    benchmarks/baselines/BENCH_decode.smoke.json --threshold "$THRESH"
  python scripts/bench_gate.py BENCH_calib.json \
    benchmarks/baselines/BENCH_calib.smoke.json --threshold "$THRESH"
  python scripts/bench_gate.py BENCH_compress.json \
    benchmarks/baselines/BENCH_compress.smoke.json --threshold "$THRESH" \
    --metric params_per_s
  # the 8-fake-device mesh oversubscribes the 2-core runner ~4x: even
  # best-of-3 windows swing ~2x under co-tenancy, so gate at 3x the base
  # threshold — still catches a broken capture path (those regress by
  # orders of magnitude) without flaking the lane; parity is gated hard
  # above regardless
  python scripts/bench_gate.py BENCH_calib_sharded.json \
    benchmarks/baselines/BENCH_calib_sharded.smoke.json \
    --threshold "$(python -c "print(min(0.9, 3*float('$THRESH')))")"
  python scripts/bench_gate.py BENCH_serve_degrade.json \
    benchmarks/baselines/BENCH_serve_degrade.smoke.json \
    --threshold "$THRESH"
  # tracing overhead: enabled tracing must keep >=95% of disabled
  # tok/s. Both rows come from one interleaved best-of-N run in one
  # process, so the ratio holds even when absolute tok/s swings under
  # co-tenancy — no baseline file, no machine calibration
  python scripts/bench_gate.py BENCH_serve_degrade.json \
    --ratio mode=trace-on mode=trace-off --min-ratio 0.95
  # boot cells are one-shot subprocesses (no best-of-N window to hide
  # scheduler noise), so gate at 2x the base threshold; the >=5x
  # warm-vs-traced ratio is asserted hard in the schema block above
  python scripts/bench_gate.py BENCH_boot.json \
    benchmarks/baselines/BENCH_boot.smoke.json \
    --metric boots_per_s \
    --threshold "$(python -c "print(min(0.9, 2*float('$THRESH')))")"
  python scripts/bench_gate.py BENCH_serve_paged.json \
    benchmarks/baselines/BENCH_serve_paged.smoke.json --threshold "$THRESH"
else
  echo "== bench regression gate skipped (BENCH_GATE=off) =="
fi

echo "CI OK"
