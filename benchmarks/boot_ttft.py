"""Boot time-to-first-token benchmark (ISSUE 7 / DESIGN.md §5.6):
cold-trace vs AOT-compiled boot of the serving stack on the llama-mini
compressed artifact.

This is the deployment cost the AOT front door exists to kill: a pod
restart under load used to pay jit tracing for the admission prefill,
the decode step and the cache scatter before emitting token one. The
AOT path (``serve/aot.py``) compiles that whole surface ahead of time
into a persistent cache keyed on the artifact fingerprint, so a warm
boot deserializes executables instead of compiling them.

Three cells, each a FRESH subprocess (an honest boot — no XLA state,
no in-process jit caches, JAX's own compilation cache disabled):

* ``traced``   — historical lazy-jit boot; TTFT pays the traces.
* ``aot_cold`` — AOT boot with an empty cache; pays the same compiles
  up front (worst case) but populates the cache.
* ``aot_warm`` — AOT boot against the populated cache; zero compiles.

Every cell must emit IDENTICAL tokens (greedy decode; the registries
may only change cost, never results) and the warm cell must report
``aot_compiles == 0`` — both asserted here, not just recorded.

Emits ``BENCH_boot.json`` rows
``{bench, config:{model, mode}, ttft_s, boots_per_s, ...}`` with
``speedup_vs_traced`` on the warm row; ``scripts/ci.sh`` gates
``boots_per_s`` against the committed smoke baseline.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import time

from benchmarks.common import ROOT, cached

BENCH_JSON = os.path.join(ROOT, "BENCH_boot.json")
ARTIFACT = os.path.join(ROOT, "runs", "boot_ttft_artifact")
AOT_CACHE = os.path.join(ROOT, "runs", "boot_ttft_aotcache")
RATIO = 0.5
MARK = "BOOTCELL "

GRID = {"slots": 4, "max_len": 256, "prompt_len": 16, "n_new": 32}
SMOKE_GRID = {"slots": 2, "max_len": 64, "prompt_len": 8, "n_new": 8}


def ensure_artifact(path: str = ARTIFACT) -> str:
    """Build (once) the llama-mini drank artifact the boot cells serve.
    Reuse is deliberate: the bench's claim is about boot mechanics, and
    all three cells share whatever artifact sits here."""
    if os.path.exists(os.path.join(path, "compressed", "manifest.json")):
        return path
    import jax

    from benchmarks.common import calib_batches
    from repro.configs import get_config
    from repro.core import compress as CC
    from repro.models import transformer as T

    cfg = get_config("llama-mini")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    calib = calib_batches(cfg, n_samples=4, seq_len=32)
    ccfg = CC.CompressionConfig(method="drank", ratio=RATIO,
                                group_size=2, beta=0.3)
    comp, plan = CC.build_plan_and_params(params, cfg, ccfg, calib)
    CC.save_plan(path, comp, plan, cfg)
    print(f"  built boot artifact at {path} "
          f"({plan.summary['achieved_ratio']:.1%} removed)", flush=True)
    return path


# ---------------------------------------------------------------------------
# child: one boot cell in a fresh process
# ---------------------------------------------------------------------------

def run_cell(cell: str, artifact: str, grid: dict) -> None:
    import numpy as np

    from repro.configs import get_config
    from repro.serve.api import ServeOptions, load_engine
    from repro.serve.engine import Request

    cfg = get_config("llama-mini")
    opts = ServeOptions(arch="llama-mini", compressed_ckpt=artifact,
                        aot=(cell != "traced"),
                        batch=grid["slots"], max_len=grid["max_len"],
                        prompt_len=grid["prompt_len"], n_new=grid["n_new"])
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=(grid["prompt_len"],),
                          dtype=np.int32)
    t0 = time.perf_counter()
    cb = load_engine(opts)
    req = Request(rid=0, tokens=prompt, n_new=grid["n_new"])
    assert cb.submit(req)
    while not req.out:                 # first step admits: prefill emits
        cb.step()
    ttft = time.perf_counter() - t0
    res = cb.run_until_drained()
    assert res.status == "drained", res.status
    keys = ("aot_compiles", "aot_cache_hits", "aot_deser_failures",
            "aot_fallbacks", "prefill_retraces", "decode_retraces")
    print(MARK + json.dumps({
        "cell": cell, "ttft_s": ttft,
        "tokens": [int(t) for t in req.out],
        "stats": {k: cb.stats.get(k, 0) for k in keys}}), flush=True)


def _spawn_cell(cell: str, artifact: str, grid: dict) -> dict:
    env = dict(os.environ)
    # JAX's own persistent compilation cache would silently warm the
    # "cold" cells; the only cache under test is serve/aot.py's
    env.pop("JAX_COMPILATION_CACHE_DIR", None)
    env["REPRO_AOT_CACHE"] = AOT_CACHE
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep + ROOT
                         + os.pathsep + env.get("PYTHONPATH", ""))
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.boot_ttft", "--cell", cell,
         "--artifact", artifact, "--grid", json.dumps(grid)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1800)
    wall = time.perf_counter() - t0
    if proc.returncode != 0:
        raise RuntimeError(f"boot cell {cell} failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith(MARK)]
    assert line, f"no {MARK!r} line from cell {cell}:\n{proc.stdout}"
    out = json.loads(line[-1][len(MARK):])
    out["proc_wall_s"] = round(wall, 2)
    return out


# ---------------------------------------------------------------------------
# parent: the three-cell experiment
# ---------------------------------------------------------------------------

def run(force: bool = False, smoke: bool = False):
    name = "boot_ttft" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        artifact = ensure_artifact()
        shutil.rmtree(AOT_CACHE, ignore_errors=True)
        cells = {}
        for cell in ("traced", "aot_cold", "aot_warm"):
            cells[cell] = _spawn_cell(cell, artifact, grid)
            s = cells[cell]["stats"]
            print(f"  boot {cell}: ttft={cells[cell]['ttft_s']:.2f}s "
                  f"compiles={s['aot_compiles']} "
                  f"hits={s['aot_cache_hits']}", flush=True)
        # correctness before speed: registries may only change cost
        tok = cells["traced"]["tokens"]
        assert cells["aot_cold"]["tokens"] == tok, \
            (tok, cells["aot_cold"]["tokens"])
        assert cells["aot_warm"]["tokens"] == tok, \
            (tok, cells["aot_warm"]["tokens"])
        warm = cells["aot_warm"]["stats"]
        assert warm["aot_compiles"] == 0, warm
        assert warm["aot_cache_hits"] > 0, warm
        speedup = cells["traced"]["ttft_s"] / cells["aot_warm"]["ttft_s"]
        rows = []
        for cell, c in cells.items():
            row = {"bench": "boot_ttft",
                   "config": {"model": f"drank@{RATIO:.0%}", "mode": cell},
                   "ttft_s": round(c["ttft_s"], 3),
                   "boots_per_s": round(1.0 / c["ttft_s"], 3),
                   "aot_compiles": c["stats"]["aot_compiles"],
                   "aot_cache_hits": c["stats"]["aot_cache_hits"]}
            if cell == "aot_warm":
                row["speedup_vs_traced"] = round(speedup, 2)
            rows.append(row)
        print(f"  boot speedup warm-AOT vs traced: {speedup:.1f}x",
              flush=True)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--cell", default="",
                    help=argparse.SUPPRESS)   # internal: child mode
    ap.add_argument("--artifact", default="", help=argparse.SUPPRESS)
    ap.add_argument("--grid", default="", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.cell:
        run_cell(args.cell, args.artifact, json.loads(args.grid))
        return 0
    out = run(force=args.force, smoke=args.smoke)
    print(json.dumps(out["rows"], indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
