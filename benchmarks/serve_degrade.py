"""Elastic-rank degradation ladder benchmark (ISSUE 6 / DESIGN.md §5):
decode throughput and TTFT of the continuous batcher at every rung of the
pow2 rank-bucket ladder sliced from one compressed artifact, plus one
adaptive run where queue pressure drives the rung selection.

This is the serving-side claim of the paper's layer-wise dynamic rank:
because the saved B/C factors are singular-value-ordered, ONE artifact
serves a whole latency/quality ladder by slicing — no re-SVD, no extra
checkpoints, one extra decode compile per rung. The benchmark quantifies
what each rung buys (tokens/s up, rank down) so the degrade policy's
thresholds are grounded in measured numbers rather than folklore.

Emits ``BENCH_serve_degrade.json`` — one row per rung with the schema
``{bench, config, tokens_per_s, ms_per_step, ttft_p50_ms}`` — alongside
the usual result cache. ``--smoke`` shrinks the model and workload for CI
(scripts/ci.sh gates tokens_per_s against a committed baseline).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ROOT, cached, calib_batches
from repro.configs import get_config
from repro.core import compress as CC
from repro.models import transformer as T
from repro.obs import trace as obs_trace
from repro.serve import admission as adm
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig

BENCH_JSON = os.path.join(ROOT, "BENCH_serve_degrade.json")

RATIO = 0.5
LEVELS = 2
GRID = {"slots": 4, "max_len": 256, "requests": 16, "prompt_len": 16,
        "n_new": 32}
SMOKE_GRID = {"slots": 2, "max_len": 64, "requests": 6, "prompt_len": 8,
              "n_new": 8}
MEASURE_REPS = 3        # best-of-N: sub-ms step windows swing ~2x under
#                         this container's scheduler noise (see fig4)


def _workload(grid, vocab, seed=0, rid_base=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=rid_base + i, n_new=grid["n_new"],
                    tokens=rng.integers(0, vocab, size=(grid["prompt_len"],),
                                        dtype=np.int32))
            for i in range(grid["requests"])]


def _ranks(tree):
    out = set()

    def walk(node):
        if isinstance(node, dict):
            if "B" in node and "C" in node:
                out.add(int(node["B"].shape[-1]))
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(tree)
    return out


def _make_batcher(params, cfg, grid, acfg=None, level=None):
    scfg = ServeConfig(batch=grid["slots"], max_len=grid["max_len"])
    cb = ContinuousBatcher(params, cfg, scfg, admission=acfg)
    if level is not None and level > 0:
        # pin a rung as a single-entry ladder: the degrade/restore policy
        # can't move off it, so the measurement is the level itself
        cb.ladder = [CC.slice_rank_ladder(params, levels=level)[-1]]
    return cb


def _measure(cb, cfg, grid, reps=MEASURE_REPS):
    """Drain the workload once untimed (pays every jit compile for this
    rung), then time ``reps`` fresh drains of the same shape and keep the
    best — sub-ms step windows swing ~2x under scheduler noise."""
    warm = _workload(grid, cfg.vocab_size, seed=1, rid_base=10_000)
    for r in warm:
        cb.submit(r)
    res = cb.run_until_drained()
    assert res.status == "drained", res.status
    best = None
    for rep in range(reps):
        m = _drain_once(cb, cfg, grid, rid_base=rep * 1000)
        if best is None or m["ms_per_step"] < best["ms_per_step"]:
            best = m
    best["_residency"] = cb.metrics()["rank_residency"]
    best["_rank_max"] = max(_ranks(cb.ladder[cb.level]) or {0})
    return best


def _drain_once(cb, cfg, grid, rid_base):
    """One timed drain of a fresh workload; the metric triple."""
    work = _workload(grid, cfg.vocab_size, rid_base=rid_base)
    steps0 = cb.metrics()["steps"]
    for r in work:
        cb.submit(r)
    t0 = time.perf_counter()
    res = cb.run_until_drained()
    dt = time.perf_counter() - t0
    assert res.status == "drained", res.status
    steps = cb.metrics()["steps"] - steps0
    toks = sum(len(r.out) for r in work)
    ttft = [r.t_first - r.t_submit for r in work]
    return {"tokens_per_s": toks / dt,
            "ms_per_step": dt / max(1, steps) * 1e3,
            "ttft_p50_ms": round(float(np.percentile(ttft, 50)) * 1e3, 3)}


def _measure_trace_overhead(comp, cfg, grid, reps=MEASURE_REPS):
    """The tracing-overhead cell (DESIGN.md §6.1's "cheap enough to leave
    on" claim, measured): the SAME full-rank batcher drains the same
    workload shape alternately with tracing disabled and enabled (a
    fresh in-memory Tracer per rep; nothing written), best-of-N per arm.
    Interleaving the arms in one process gives scheduler noise an equal
    shot at both, so the off/on ratio is meaningful even when absolute
    tok/s swings — scripts/ci.sh gates that ratio at >=0.95."""
    cb = _make_batcher(comp, cfg, grid)
    warm = _workload(grid, cfg.vocab_size, seed=1, rid_base=90_000)
    for r in warm:
        cb.submit(r)
    res = cb.run_until_drained()
    assert res.status == "drained", res.status
    best = {"off": None, "on": None}
    for rep in range(reps):
        for arm in ("off", "on"):
            base = 100_000 + rep * 2000 + (1000 if arm == "on" else 0)
            if arm == "on":
                obs_trace.enable(obs_trace.Tracer())
            try:
                m = _drain_once(cb, cfg, grid, rid_base=base)
            finally:
                if arm == "on":
                    obs_trace.disable()
            if best[arm] is None or m["ms_per_step"] < \
                    best[arm]["ms_per_step"]:
                best[arm] = m
    return best["off"], best["on"]


def run(force: bool = False, smoke: bool = False):
    name = "serve_degrade" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        cfg = get_config("llama-mini")
        if smoke:
            cfg = cfg.reduced()
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        calib = calib_batches(cfg, n_samples=4, seq_len=32)
        ccfg = CC.CompressionConfig(method="drank", ratio=RATIO,
                                    group_size=2, beta=0.3)
        comp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib)
        rows = []
        for level in range(LEVELS + 1):
            cb = _make_batcher(comp, cfg, grid, level=level)
            m = _measure(cb, cfg, grid)
            rank_max = m.pop("_rank_max")
            m.pop("_residency")
            rows.append({"bench": "serve_degrade",
                         "config": {"model": f"drank@{RATIO:.0%}",
                                    "mode": "pinned", "level": level},
                         "rank_max": rank_max, **m})
            print(f"  sdg level={level} rank_max={rank_max}: "
                  f"{m['tokens_per_s']:.0f} tok/s "
                  f"ttft_p50={m['ttft_p50_ms']:.0f}ms", flush=True)
        # adaptive run: the policy itself picks rungs under queue pressure
        acfg = adm.AdmissionConfig(elastic=True, elastic_levels=LEVELS,
                                   degrade_above=grid["slots"],
                                   restore_below=1)
        cb = _make_batcher(comp, cfg, grid, acfg=acfg)
        m = _measure(cb, cfg, grid)
        residency = m.pop("_residency")
        m.pop("_rank_max")
        rows.append({"bench": "serve_degrade",
                     "config": {"model": f"drank@{RATIO:.0%}",
                                "mode": "elastic", "level": -1},
                     "rank_residency": residency, **m})
        print(f"  sdg elastic residency={residency}: "
              f"{m['tokens_per_s']:.0f} tok/s", flush=True)
        # tracing-overhead pair: off vs on, interleaved in this process
        off, on = _measure_trace_overhead(comp, cfg, grid)
        for mode, m in (("trace-off", off), ("trace-on", on)):
            rows.append({"bench": "serve_degrade",
                         "config": {"model": f"drank@{RATIO:.0%}",
                                    "mode": mode, "level": 0}, **m})
        ratio = on["tokens_per_s"] / off["tokens_per_s"]
        print(f"  sdg tracing overhead: {off['tokens_per_s']:.0f} -> "
              f"{on['tokens_per_s']:.0f} tok/s "
              f"(ratio {ratio:.3f})", flush=True)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    keep = ("bench", "config", "tokens_per_s", "ms_per_step",
            "ttft_p50_ms", "rank_max", "rank_residency")
    payload = [{k: r[k] for k in keep if k in r} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    print(json.dumps(out["rows"], indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
