"""Paper Table 1 / Figure 2 analog: effective-rank profiles.

Claims reproduced on the trained model:
  (1) R_eff(W^V) >> R_eff(W^Q), R_eff(W^K) at (almost) every depth — the
      imbalance that motivates the β rebalance;
  (2) the depth profile is non-uniform (the premise of layer-wise
      allocation).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached, calib_batches, load_trained
from repro.core import compress as CC
from repro.core import numerics as num
from repro.core.capture import to_list_params
from repro.core.groups import build_groups, enumerate_matrices


def run(force: bool = False, group_size: int = 2):
    def compute():
        cfg, params, _ = load_trained()
        calib = calib_batches(cfg, n_samples=16)
        lp = to_list_params(params, cfg)
        col = CC.calibrate(lp, cfg, calib)
        refs = enumerate_matrices(lp, cfg, include_experts=False)
        groups = build_groups(refs, cfg, group_size, gqa_group_one=False)
        rows = []
        for g in groups:
            if g.mtype not in ("q", "k", "v", "up", "gate", "down", "o"):
                continue
            G = None
            W = []
            for m in g.members:
                gr = col.gram[m.tag]
                G = gr if G is None else G + gr
                W.append(np.asarray(lp_get(lp, m.path)["w"],
                                    dtype=np.float64))
            wh = num.cholesky_whitener(G)
            _, sig, _ = num.whitened_svd(np.concatenate(W, axis=1), wh)
            rows.append({"type": g.mtype, "group": g.gid,
                         "layer0": g.members[0].layer,
                         "reff": num.effective_rank(sig)})
        return {"rows": rows, "group_size": group_size}

    return cached("table1_effective_rank", compute, force)


def lp_get(tree, path):
    node = tree
    for k in path:
        node = node[k]
    return node


def main(force: bool = False):
    out = run(force)
    by = {}
    for row in out["rows"]:
        by.setdefault(row["type"], []).append((row["layer0"], row["reff"]))
    print("effective ranks by depth (grouped, n=%d)" % out["group_size"])
    for t in ("v", "k", "q", "up", "gate", "down", "o"):
        if t not in by:
            continue
        prof = " ".join(f"{r:7.1f}" for _, r in sorted(by[t]))
        print(f"  {t:5s} {prof}")
    vmean = np.mean([r for _, r in by.get("v", [(0, 0)])])
    qmean = np.mean([r for _, r in by.get("q", [(0, 1)])])
    kmean = np.mean([r for _, r in by.get("k", [(0, 1)])])
    print(f"  mean: V={vmean:.1f} Q={qmean:.1f} K={kmean:.1f} "
          f"(paper claim: V >> Q,K -> ratio {vmean/max(qmean,kmean):.2f}x)")
    return out


if __name__ == "__main__":
    main()
