"""Paper Table 3 analog: PPL (+ next-token accuracy as the zero-shot
analog) of the trained llama-mini under all six methods × ratios 20–50%.

Claim reproduced: D-Rank <= Basis Sharing <= SVD-LLM <= ASVD << FWSVD/SVD
in PPL at every ratio, and the margin grows with the ratio.
"""
from __future__ import annotations

from benchmarks.common import (cached, calib_batches, eval_batches,
                               load_trained, ppl_of)
from repro.core import compress as CC

RATIOS = (0.2, 0.3, 0.4, 0.5)
# dranke = our beyond-paper spectra-driven allocator (same infrastructure)
METHODS = ("svd", "fwsvd", "asvd", "svdllm", "basis", "drank", "dranke")


def run(force: bool = False, ratios=RATIOS, methods=METHODS,
        group_size: int = 2, beta: float = 0.3):
    def compute():
        cfg, params, step = load_trained()
        calib = calib_batches(cfg, n_samples=16)
        evalb = eval_batches(cfg, n_batches=4)
        rows = [{"method": "original", "ratio": 0.0,
                 **ppl_of(params, cfg, evalb), "ckpt_step": step}]
        # share one calibration pass across all cholesky-family methods
        from repro.core.capture import to_list_params
        lp = to_list_params(params, cfg)
        col = CC.calibrate(lp, cfg, calib)
        for ratio in ratios:
            for method in methods:
                ccfg = CC.CompressionConfig(
                    method=method, ratio=ratio, group_size=group_size,
                    beta=beta, refine=(ratio >= 0.4))
                new_lp, plan = CC.build_plan_and_params(
                    params, cfg, ccfg, calib, collector=col)
                m = ppl_of(new_lp, cfg, evalb)
                rows.append({"method": method, "ratio": ratio, **m,
                             "achieved_ratio":
                             plan.summary["achieved_ratio"]})
                print(f"  t3 {method:7s} @{ratio:.0%}: "
                      f"ppl={m['ppl']:.2f} acc={m['accuracy']:.3f}",
                      flush=True)
        return {"rows": rows}

    return cached("table3_ppl", compute, force)


def main(force: bool = False):
    out = run(force)
    print(f"{'method':10s} " + " ".join(f"{r:>8.0%}" for r in RATIOS))
    base = {}
    for row in out["rows"]:
        base.setdefault(row["method"], {})[row.get("ratio", 0)] = row["ppl"]
    for m in ("original",) + METHODS:
        if m not in base:
            continue
        cells = [f"{base[m].get(r, float('nan')):8.2f}" for r in RATIOS] \
            if m != "original" else [f"{base[m][0.0]:8.2f}"]
        print(f"{m:10s} " + " ".join(cells))
    return out


if __name__ == "__main__":
    main()
