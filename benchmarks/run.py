"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table3] [--force]

Emits a ``name,us_per_call,derived`` CSV row per benchmark (us_per_call =
wall time of the bench; derived = its headline metric). All benches cache
to experiments/results/*.json, so re-runs are free.
"""
from __future__ import annotations

import argparse
import functools
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip fig3 (LoRA) and fig4 (wall-clock)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny models/grids where a bench supports it")
    ap.add_argument("--json", nargs="?", const="BENCH_decode.json",
                    default="", metavar="PATH",
                    help="also write decode-path rows "
                         "({bench, config, tokens_per_s, ms_per_step}) "
                         "to PATH")
    args = ap.parse_args(argv)

    from benchmarks import (calib_capture, calib_sharded, compress_path,
                            fig3_lora, fig4_decode_path, fig4_throughput,
                            serve_degrade, table1_effective_rank,
                            table2_gqa, table3_ppl, table5_beta,
                            table8_calib)

    def d_table3(out):
        rows = {(r["method"], r.get("ratio")): r["ppl"]
                for r in out["rows"]}
        dr = rows.get(("drank", 0.2))
        sv = rows.get(("svdllm", 0.2))
        return f"drank@20%={dr:.2f};svdllm@20%={sv:.2f}"

    def d_table5(out):
        best = min((r for r in out["rows"] if r["method"] == "drank"),
                   key=lambda r: r["ppl"])
        return f"best_beta={best['beta']};ppl={best['ppl']:.2f}"

    def d_table2(out):
        b = {r.get("group"): r["ppl"] for r in out["rows"]
             if r["method"] == "basis"}
        return f"basis_n1={b.get(1, 0):.2f};basis_n4={b.get(4, 0):.2f}"

    def d_table8(out):
        dr = [r for r in out["rows"] if r["method"] == "drank"]
        return f"drank_orig_ppl={min(r['ppl_orig'] for r in dr):.2f}"

    def d_table1(out):
        import numpy as np
        by = {}
        for r in out["rows"]:
            by.setdefault(r["type"], []).append(r["reff"])
        return (f"V/Q_reff_ratio="
                f"{np.mean(by['v']) / max(np.mean(by['q']), 1e-9):.2f}")

    def d_fig4(out):
        d = next(r for r in out["rows"] if r["model"] == "dense")
        c = max((r for r in out["rows"] if r["model"] == "drank"),
                key=lambda r: r["ratio"])
        return (f"speedup@{c['ratio']:.0%}="
                f"{c['tokens_per_s'] / d['tokens_per_s']:.2f}x")

    def d_fig3(out):
        dr = [r for r in out["rows"] if r["method"] == "drank"]
        return f"drank_after={min(r['ppl_after'] for r in dr):.2f}"

    def d_fig4d(out):
        jnp_rows = [r for r in out["rows"]
                    if r["config"]["path"] == "jnp"]
        cell = lambda r: (r["config"]["batch"], r["config"]["cache_len"])
        dense = {cell(r): r["tokens_per_s"] for r in jnp_rows
                 if r["config"]["model"] == "dense"}
        # speedup per matching (batch, cache_len) cell, best cell reported
        best = max(r["tokens_per_s"] / dense[cell(r)] for r in jnp_rows
                   if r["config"]["model"] != "dense" and cell(r) in dense)
        return f"decode_speedup={best:.2f}x"

    def d_calib(out):
        by = {r["config"]["path"]: r["tokens_per_s"] for r in out["rows"]}
        ratio = by["jit-device"] / max(by["eager-host"], 1e-9)
        return f"stream_speedup={ratio:.0f}x"

    def d_compress(out):
        dev = max((r for r in out["rows"] if "speedup" in r),
                  key=lambda r: r["speedup"])
        return f"device_speedup={dev['speedup']:.1f}x"

    def d_calib_sharded(out):
        by = {r["config"]["path"]: r for r in out["rows"]}
        ratio = (by["mesh-sharded"]["tokens_per_s"]
                 / max(by["mesh-replicated"]["tokens_per_s"], 1e-9))
        err = max(r["max_rel_err"] for r in out["rows"])
        return f"sharded_vs_replicated={ratio:.2f}x;err={err:.0e}"

    def d_serve_degrade(out):
        by = {r["config"]["level"]: r["tokens_per_s"]
              for r in out["rows"] if r["config"]["mode"] == "pinned"}
        deepest = max(by)
        return (f"degrade_speedup@L{deepest}="
                f"{by[deepest] / max(by[0], 1e-9):.2f}x")

    fig4_decode = functools.partial(fig4_decode_path.run, smoke=args.smoke)
    calib = functools.partial(calib_capture.run, smoke=args.smoke)
    # runs in a subprocess when this process lacks the forced 8-device
    # host platform (see benchmarks/calib_sharded.py)
    calib_sh = functools.partial(calib_sharded.run, smoke=args.smoke)
    compress = functools.partial(compress_path.run, smoke=args.smoke)
    degrade = functools.partial(serve_degrade.run, smoke=args.smoke)

    benches = [
        ("table1_effective_rank", table1_effective_rank.run, d_table1),
        ("table3_ppl", table3_ppl.run, d_table3),
        ("table5_beta", table5_beta.run, d_table5),
        ("table2_gqa", table2_gqa.run, d_table2),
        ("table8_calib", table8_calib.run, d_table8),
        ("fig4_throughput", fig4_throughput.run, d_fig4),
        ("fig4_decode_path", fig4_decode, d_fig4d),
        ("calib_capture", calib, d_calib),
        ("calib_sharded", calib_sh, d_calib_sharded),
        ("compress_path", compress, d_compress),
        ("serve_degrade", degrade, d_serve_degrade),
        ("fig3_lora", fig3_lora.run, d_fig3),
    ]
    if args.skip_slow:
        benches = [b for b in benches if not b[0].startswith("fig")]
    if args.only:
        benches = [b for b in benches if args.only in b[0]]

    print("name,us_per_call,derived")
    rc = 0
    json_rows = []
    for name, fn, derive in benches:
        try:
            out = fn(force=args.force)
            us = out.get("_wall_s", 0.0) * 1e6
            print(f"{name},{us:.0f},{derive(out)}", flush=True)
            json_rows += [r for r in out.get("rows", [])
                          if "tokens_per_s" in r and "bench" in r
                          and "ms_per_step" in r]   # decode-path schema

        except Exception as e:
            rc = 1
            traceback.print_exc()
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
    if args.json:
        path = fig4_decode_path.write_bench_json(json_rows, args.json)
        print(f"# wrote {len(json_rows)} rows to {path}", flush=True)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
