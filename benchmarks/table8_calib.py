"""Paper Table 8 analog: calibration-data transfer.

The paper calibrates on C4 and evaluates on both C4 and WikiText-2. Our
analog: calibrate the compression on a *shifted* synthetic language
(different Zipf/topic seed => different token distribution) and evaluate on
both the shifted and the original language. Claim: D-Rank transfers better
out-of-distribution than Basis Sharing / SVD-LLM at every group size.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (cached, data_config, eval_batches,
                               load_trained, ppl_of)
from repro.core import compress as CC
from repro.data.synthetic import SyntheticLM

RATIO = 0.2


def _shifted_batches(cfg, n_samples=16, batch=8, seq=128):
    """Same language (same seed => same successor maps) but a shifted
    token distribution: flatter Zipf tail + faster topic mixing — the
    C4-vs-WikiText analog (related domain, different statistics)."""
    base = data_config(cfg, seq, seed=0)
    dcfg = dataclasses.replace(base, zipf_s=1.05, topic_flip=0.08)
    lm = SyntheticLM(dcfg)
    out = []
    for i in range(0, n_samples, batch):
        rows = np.arange(i, i + batch)
        out.append({"tokens": jnp.asarray(lm.sample_rows(10_001, rows))})
    return out


def run(force: bool = False):
    def compute():
        cfg, params, _ = load_trained()
        calib_shifted = _shifted_batches(cfg)
        eval_orig = eval_batches(cfg, n_batches=4)
        eval_shift = _shifted_batches(cfg, n_samples=32)[:4]
        from repro.core.capture import to_list_params
        col = CC.calibrate(to_list_params(params, cfg), cfg, calib_shifted)
        rows = []
        for method, groups in (("svdllm", (1,)), ("basis", (2, 4)),
                               ("drank", (2, 4))):
            for n in groups:
                ccfg = CC.CompressionConfig(method=method, ratio=RATIO,
                                            group_size=n, beta=0.3)
                lp, _ = CC.build_plan_and_params(params, cfg, ccfg,
                                                 calib_shifted,
                                                 collector=col)
                row = {"method": method, "group": n,
                       "ppl_shifted": ppl_of(lp, cfg, eval_shift)["ppl"],
                       "ppl_orig": ppl_of(lp, cfg, eval_orig)["ppl"]}
                rows.append(row)
                print(f"  t8 {method} n={n}: shifted={row['ppl_shifted']:.2f}"
                      f" orig={row['ppl_orig']:.2f}", flush=True)
        return {"ratio": RATIO, "rows": rows}

    return cached("table8_calib", compute, force)


def main(force: bool = False):
    out = run(force)
    for row in out["rows"]:
        print(f"  {row['method']:8s} n={row['group']} "
              f"calib-dist ppl={row['ppl_shifted']:.3f} "
              f"orig-dist ppl={row['ppl_orig']:.3f}")
    return out


if __name__ == "__main__":
    main()
