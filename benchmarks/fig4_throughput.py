"""Paper Figure 4 analog: decode throughput of the dense model vs
compressed models at 20–50% ratios (CPU wall-clock on llama-mini; the
factorized matmuls read fewer weight bytes, so compressed decode is faster
— the paper's >60% gain at 50% is HBM-bandwidth bound on GPU, here the
same effect shows at CPU-memory bandwidth)."""
from __future__ import annotations

from benchmarks.common import cached, calib_batches, load_trained
from repro.core import compress as CC
from repro.serve.engine import Engine, ServeConfig

RATIOS = (0.2, 0.3, 0.4, 0.5)


def run(force: bool = False):
    def compute():
        cfg, params, _ = load_trained()
        calib = calib_batches(cfg, n_samples=8)
        from repro.core.capture import to_list_params
        col = CC.calibrate(to_list_params(params, cfg), cfg, calib)
        scfg = ServeConfig()
        rows = []
        eng = Engine(params, cfg, scfg)
        m = eng.measure_decode_throughput(batch=8, prompt_len=32, n_new=48)
        rows.append({"model": "dense", "ratio": 0.0, **m})
        print(f"  f4 dense: {m['tokens_per_s']:.0f} tok/s", flush=True)
        for ratio in RATIOS:
            for method in ("basis", "drank"):
                ccfg = CC.CompressionConfig(method=method, ratio=ratio,
                                            group_size=2, beta=0.3)
                lp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                                 collector=col)
                eng = Engine(lp, cfg, scfg)
                m = eng.measure_decode_throughput(batch=8, prompt_len=32,
                                                  n_new=48)
                rows.append({"model": method, "ratio": ratio, **m})
                print(f"  f4 {method}@{ratio:.0%}: "
                      f"{m['tokens_per_s']:.0f} tok/s", flush=True)
        return {"rows": rows}

    return cached("fig4_throughput", compute, force)


def main(force: bool = False):
    out = run(force)
    for row in out["rows"]:
        print(f"  {row['model']:6s} @{row['ratio']:.0%}: "
              f"{row['tokens_per_s']:8.0f} tok/s "
              f"({row['ms_per_step']:.1f} ms/step)")
    return out


if __name__ == "__main__":
    main()
