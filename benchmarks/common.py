"""Shared benchmark plumbing: trained-model loading, data, PPL eval,
result caching (every bench caches to experiments/results/<name>.json so
the aggregate runner is resumable on this 1-core container)."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import store
from repro.configs import get_config
from repro.data.synthetic import DataConfig, SyntheticLM, calibration_batches
from repro.train import step as TS

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "experiments", "results")
RUNS = os.path.join(ROOT, "runs")

EVAL_SEED_STEP = 777_001        # disjoint from train steps and calib seed


def result_path(name: str) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    return os.path.join(RESULTS, f"{name}.json")


def cached(name: str, fn, force: bool = False):
    path = result_path(name)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    out = fn()
    out["_wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def data_config(cfg, seq_len: int = 128, seed: int = 0) -> DataConfig:
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=8, seed=seed)


def load_trained(arch: str = "llama-mini", run: str = "mini_mha",
                 overrides: Optional[Dict] = None):
    """Load the latest checkpoint of a background training run."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    ckpt_dir = os.path.join(RUNS, run)
    step, state = store.restore(ckpt_dir, state)
    return cfg, state.params, step


def eval_batches(cfg, n_batches: int = 4, batch: int = 8,
                 seq_len: int = 128, seed: int = 0) -> List[Dict]:
    lm = SyntheticLM(data_config(cfg, seq_len, seed))
    out = []
    for i in range(n_batches):
        rows = np.arange(i * batch, (i + 1) * batch)
        out.append({"tokens": jnp.asarray(
            lm.sample_rows(EVAL_SEED_STEP, rows))})
    return out


def calib_batches(cfg, n_samples: int = 16, batch: int = 8,
                  seq_len: int = 128, seed: int = 0) -> List[Dict]:
    dcfg = data_config(cfg, seq_len, seed)
    return [{"tokens": jnp.asarray(b["tokens"])}
            for b in calibration_batches(dcfg, n_samples, batch)]


def ppl_of(params, cfg, batches) -> Dict[str, float]:
    return TS.evaluate_ppl(params, cfg, batches)


def calib_max_rel_err(col, oracle) -> float:
    """Worst relative error of a captured Collector vs the eager fp64
    oracle, over every tag's Gram AND abs-sum statistics. Tags captured
    as streaming-whitening factors compare through RᵀR (the Gram the
    factor represents) — shared by the capture benches so the CI parity
    bar stays uniform across the single-device and mesh paths."""
    worst = 0.0
    for tag in oracle.gram:
        got = (col.gram[tag] if tag in col.gram
               else col.chol[tag].T @ col.chol[tag])
        ref = oracle.gram[tag]
        worst = max(worst, float(np.abs(got - ref).max()
                                 / (np.abs(ref).max() + 1e-12)))
        aref = oracle.absmean[tag]
        worst = max(worst, float(np.abs(col.absmean[tag] - aref).max()
                                 / (np.abs(aref).max() + 1e-12)))
    return worst
