"""Paper Figure 3 analog: LoRA fine-tuning recovery of compressed models.

Each compressed model gets the paper's recipe (lora_r=8, alpha=32,
lr=1e-4) for a short budget on the training stream. Claim: D-Rank+LoRA
stays below SVD-LLM+LoRA / Basis-Sharing+LoRA, with the gap widening at
aggressive ratios.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (cached, calib_batches, data_config,
                               eval_batches, load_trained, ppl_of)
from repro.core import compress as CC
from repro.data.synthetic import ShardedLoader
from repro.train.lora import lora_finetune

RATIOS = (0.3, 0.5)
METHODS = ("svdllm", "basis", "drank")
FT_STEPS = 60


def _train_stream(cfg):
    loader = ShardedLoader(data_config(cfg))
    step = 500_000     # disjoint range from pre-training steps
    while True:
        yield {k: jnp.asarray(v) for k, v in loader.batch(step).items()}
        step += 1


def run(force: bool = False):
    def compute():
        cfg, params, _ = load_trained()
        calib = calib_batches(cfg, n_samples=16)
        evalb = eval_batches(cfg, n_batches=4)
        from repro.core.capture import to_list_params
        col = CC.calibrate(to_list_params(params, cfg), cfg, calib)
        rows = []
        for ratio in RATIOS:
            for method in METHODS:
                ccfg = CC.CompressionConfig(method=method, ratio=ratio,
                                            group_size=2, beta=0.3)
                lp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                                 collector=col)
                before = ppl_of(lp, cfg, evalb)["ppl"]
                tuned, hist = lora_finetune(lp, cfg, _train_stream(cfg),
                                            steps=FT_STEPS)
                after = ppl_of(tuned, cfg, evalb)["ppl"]
                rows.append({"method": method, "ratio": ratio,
                             "ppl_before": before, "ppl_after": after})
                print(f"  f3 {method}@{ratio:.0%}: {before:.2f} -> "
                      f"{after:.2f}", flush=True)
        return {"rows": rows, "ft_steps": FT_STEPS}

    return cached("fig3_lora", compute, force)


def main(force: bool = False):
    out = run(force)
    for row in out["rows"]:
        print(f"  {row['method']:8s}@{row['ratio']:.0%} "
              f"ppl {row['ppl_before']:.2f} -> {row['ppl_after']:.2f}")
    return out


if __name__ == "__main__":
    main()
