"""Calibration-capture throughput: eager-host oracle vs jit/device
streaming capture (the PR-2 tentpole; DESIGN.md §7).

Three execution paths per grid cell:
  eager-host        the fp64 numpy Collector — forward runs op-by-op with a
                    host round trip per tagged linear (the seed behavior)
  jit-device        StreamingCalibrator — one jit-compiled step per batch,
                    fp32 Gram partials reduced on device (XLA dot on this
                    CPU runner; Pallas ``gram_blocked`` on TPU), fp64 host
                    flush every few batches
  pallas-interpret  the smallest cell again with the Pallas gram kernel
                    under the interpreter: CORRECTNESS evidence that the
                    TPU deploy path runs end to end (timing is not a perf
                    claim)

Every streaming row also records ``max_rel_err`` against the eager fp64
oracle — the acceptance bar is 1e-4 on every tag and is asserted here, so
the CI smoke run (scripts/ci.sh) re-proves parity on every push.

Emits ``BENCH_calib.json`` at the repo root with the schema
``{bench, config, tokens_per_s, ms_per_batch}``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import ROOT, cached, calib_batches, \
    calib_max_rel_err as _max_rel_err
from repro.configs import get_config
from repro.core.capture import Collector, StreamingCalibrator, \
    to_list_params
from repro.core.compress import calibrate
from repro.models import transformer as T

BENCH_JSON = os.path.join(ROOT, "BENCH_calib.json")

GRID = {"batch": 8, "seq": 128, "n_batches": 8}
SMOKE_GRID = {"batch": 2, "seq": 32, "n_batches": 3}
PARITY_TOL = 1e-4


def _eager_capture(lp, cfg, batches) -> Collector:
    return calibrate(lp, cfg, batches, streaming=False)


def run(force: bool = False, smoke: bool = False):
    name = "calib_capture" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        cfg = get_config("llama-mini")
        if smoke:
            cfg = cfg.reduced()
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        lp = to_list_params(params, cfg)
        batches = calib_batches(cfg, n_samples=grid["batch"]
                                * grid["n_batches"], batch=grid["batch"],
                                seq_len=grid["seq"])
        tokens = grid["batch"] * grid["seq"] * grid["n_batches"]
        rows = []

        def row(path, dt, extra=None):
            r = {"bench": "calib_capture",
                 "config": {"path": path, **grid},
                 "tokens_per_s": tokens / dt,
                 "ms_per_batch": dt / grid["n_batches"] * 1000.0}
            r.update(extra or {})
            rows.append(r)
            print(f"  calib {path:16s}: {r['tokens_per_s']:8.0f} tok/s "
                  f"({r['ms_per_batch']:.0f} ms/batch)", flush=True)
            return r

        # -- eager host oracle (also the parity reference) ------------------
        t0 = time.perf_counter()
        oracle = _eager_capture(lp, cfg, batches)
        row("eager-host", time.perf_counter() - t0)

        # -- jit/device streaming ------------------------------------------
        # pass 1 (untimed) pays the compile and covers every batch exactly
        # once — the finalized stats feed the parity bar vs the oracle
        cal = StreamingCalibrator(lp, cfg)
        for b in batches:
            cal.ingest(b)
        err = _max_rel_err(cal.finalize(), oracle)
        assert err < PARITY_TOL, f"streaming capture diverged: {err:.2e}"
        # pass 2 (timed): finalize reset the device accumulators, so
        # re-ingesting is steady-state; the smoke cell is ~4 ms/batch, so
        # repeat the batch list enough to widen the timing window well
        # past scheduler noise (the CI gate diffs this number)
        rounds = 25 if smoke else 2
        t0 = time.perf_counter()
        for _ in range(rounds):
            for b in batches:
                cal.ingest(b)
        cal.sync()
        dt = time.perf_counter() - t0
        n_timed = grid["n_batches"] * rounds
        r = {"bench": "calib_capture",
             "config": {"path": "jit-device", **grid},
             "tokens_per_s": grid["batch"] * grid["seq"] * n_timed / dt,
             "ms_per_batch": dt / n_timed * 1000.0, "max_rel_err": err}
        rows.append(r)
        print(f"  calib {'jit-device':16s}: {r['tokens_per_s']:8.0f} tok/s "
              f"({r['ms_per_batch']:.0f} ms/batch, rel err {err:.1e}, "
              f"timed {n_timed} batches)", flush=True)

        # -- Pallas gram kernel, interpret mode (deploy-path evidence) ------
        pal = StreamingCalibrator(lp, cfg, use_kernel=True, flush_every=1)
        t0 = time.perf_counter()
        pal.ingest(batches[0])
        pal.sync()
        dt1 = time.perf_counter() - t0
        one = _eager_capture(lp, cfg, batches[:1])
        err = _max_rel_err(pal.finalize(), one)
        assert err < PARITY_TOL, f"pallas gram diverged: {err:.2e}"
        r = {"bench": "calib_capture",
             "config": {"path": "pallas-interpret", "batch": grid["batch"],
                        "seq": grid["seq"], "n_batches": 1},
             "tokens_per_s": grid["batch"] * grid["seq"] / dt1,
             "ms_per_batch": dt1 * 1000.0, "max_rel_err": err}
        rows.append(r)
        print(f"  calib pallas-interpret: ok (rel err {err:.1e})",
              flush=True)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    payload = [{"bench": r["bench"], "config": r["config"],
                "tokens_per_s": r["tokens_per_s"],
                "ms_per_batch": r["ms_per_batch"],
                **({"max_rel_err": r["max_rel_err"]}
                   if "max_rel_err" in r else {})} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + grid (CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    for r in out["rows"]:
        c = r["config"]
        print(f"  {c['path']:16s} b={c['batch']} s={c['seq']} "
              f"n={c['n_batches']} {r['tokens_per_s']:8.0f} tok/s")
    print(f"  wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
