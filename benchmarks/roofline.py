"""Roofline reader: aggregates experiments/dryrun/*/*.json into the
EXPERIMENTS.md §Roofline table (compute/memory/collective terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, one-line lever per cell)."""
from __future__ import annotations

import json
import os
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN = os.path.join(ROOT, "experiments", "dryrun")

LEVERS = {
    "compute_s": "raise MXU occupancy: bigger per-device microbatch or "
                 "causal block-skip in attention",
    "memory_s": "fuse attention tiles in VMEM (Pallas flash), bf16 "
                "collective/residual dtype, D-Rank factorized weights cut "
                "weight reads",
    "collective_s": "shrink TP all-reduce payload (bf16), overlap with "
                    "compute via latency-hiding scheduler, or shift "
                    "sharding from TP toward FSDP",
}


def load_cells(mesh: str = "single", tag: str = "") -> List[Dict]:
    d = os.path.join(DRYRUN, mesh)
    out = []
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        if tag and not name.endswith(f"__{tag}.json"):
            continue
        if not tag and name.count("__") > 1:
            continue
        with open(os.path.join(d, name)) as f:
            out.append(json.load(f))
    return out


def markdown_table(mesh: str = "single", tag: str = "") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful flops | bottleneck lever |",
            "|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh, tag):
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skip | — | {c['reason'][:40]} |")
            continue
        if "error" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"ERROR | — | {c['error'][:40]} |")
            continue
        r = c["roofline"]
        dom = r["dominant"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{dom.replace('_s', '')} | {r['useful_flops_ratio']:.3f} | "
            f"{LEVERS[dom][:60]} |")
    return "\n".join(rows)


def main():
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        if not cells:
            continue
        ok = sum(1 for c in cells if "roofline" in c)
        sk = sum(1 for c in cells if c.get("skipped"))
        er = sum(1 for c in cells if "error" in c)
        print(f"== {mesh}-pod: {ok} ok / {sk} skip / {er} error ==")
        print(markdown_table(mesh))
        print()


if __name__ == "__main__":
    main()
