"""Sharded streaming-calibration capture on a multi-device host mesh
(the PR-5 tentpole; DESIGN.md §1.6).

Forces ``--xla_force_host_platform_device_count=8`` BEFORE jax initializes
so the (data=8) mesh paths run with real per-device buffers, and measures
three capture routes per grid cell:

  mesh-replicated   per-shard partial Grams psum'd into replicated (D,D)
                    accumulators (the PR-2 layout, now pipelined)
  mesh-sharded      (D,D) accumulators row-sharded over the data axis —
                    no device materializes a full (D,D); the fold
                    all-gathers activation rows and GEMMs its row block
  mesh-whiten       streaming whitening per shard (QR updates, no Gram
                    anywhere), factors tree-reduced at finalize

Every row records ``max_rel_err`` against the eager fp64 oracle (grams /
RᵀR of factors), so the CI smoke run re-proves mesh parity on every push.
If the process already initialized jax with fewer devices (e.g. under
``benchmarks.run``), the bench re-executes itself in a subprocess and
reads the cached result.

Emits ``BENCH_calib_sharded.json`` at the repo root with the schema
``{bench, config, tokens_per_s, ms_per_batch, max_rel_err}``.
"""
from __future__ import annotations

import os

if __name__ == "__main__":
    # only when run as a standalone process: the flag must land before
    # jax's backend init, and must NOT leak into sibling benches when
    # this module is merely imported by benchmarks.run (their timings
    # assume the real single device — run() re-execs a subprocess then)
    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse      # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from benchmarks.common import (ROOT, cached,                # noqa: E402
                               calib_max_rel_err as _max_rel_err,
                               result_path)
from repro.configs import get_config                        # noqa: E402
from repro.core.capture import (StreamingCalibrator,  # noqa: E402
                                to_list_params)
from repro.core.compress import calibrate                   # noqa: E402
from repro.launch.mesh import make_host_mesh                # noqa: E402
from repro.models import transformer as T                   # noqa: E402

BENCH_JSON = os.path.join(ROOT, "BENCH_calib_sharded.json")
DEVICES = 8

GRID = {"batch": 8, "seq": 128, "n_batches": 8, "devices": DEVICES}
SMOKE_GRID = {"batch": 8, "seq": 32, "n_batches": 3, "devices": DEVICES}
PARITY_TOL = 1e-4


def _cfg(smoke: bool):
    cfg = get_config("llama-mini")
    if smoke:
        cfg = cfg.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=256)
    return cfg


def _batches(cfg, grid):
    key = jax.random.PRNGKey(7)
    return [{"tokens": jax.random.randint(
        jax.random.fold_in(key, i), (grid["batch"], grid["seq"]),
        0, cfg.vocab_size)} for i in range(grid["n_batches"])]


def _run_inprocess(smoke: bool):
    grid = SMOKE_GRID if smoke else GRID
    cfg = _cfg(smoke)
    mesh = make_host_mesh(data=DEVICES, model=1)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg, grid)
    oracle = calibrate(lp, cfg, batches, streaming=False)
    tokens = grid["batch"] * grid["seq"] * grid["n_batches"]
    rounds = 10 if smoke else 2
    rows = []

    paths = {
        "mesh-replicated": dict(shard_grams_above=0),
        "mesh-sharded": dict(shard_grams_above=1),
        "mesh-whiten": dict(whiten_tags=True),
    }
    for path, kw in paths.items():
        # pass 1 (untimed): pays compile, covers every batch once — the
        # finalized stats feed the parity bar vs the eager fp64 oracle
        cal = StreamingCalibrator(lp, cfg, mesh=mesh, **kw)
        for b in batches:
            cal.ingest(b)
        err = _max_rel_err(cal.finalize(), oracle)
        assert err < PARITY_TOL, f"{path} diverged: {err:.2e}"
        # pass 2 (timed): finalize reset the accumulators → steady state;
        # repeat the batch list to widen the window past scheduler noise
        # and take the best of 3 windows — the 8-fake-device mesh
        # oversubscribes this container ~4×, so single windows swing 2-3×
        # (same best-of-N convention as benchmarks/compress_path.py)
        dt = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(rounds):
                for b in batches:
                    cal.ingest(b)
            cal.sync()
            dt = min(dt, time.perf_counter() - t0)
        n_timed = grid["n_batches"] * rounds
        rows.append({
            "bench": "calib_sharded",
            "config": {"path": path, **grid},
            "tokens_per_s": tokens * rounds / dt,
            "ms_per_batch": dt / n_timed * 1000.0,
            "max_rel_err": err,
        })
        print(f"  calib {path:16s}: {rows[-1]['tokens_per_s']:8.0f} tok/s "
              f"({rows[-1]['ms_per_batch']:.0f} ms/batch, "
              f"rel err {err:.1e})", flush=True)
    return {"rows": rows}


def run(force: bool = False, smoke: bool = False):
    name = "calib_sharded" + ("_smoke" if smoke else "")
    if len(jax.devices()) < DEVICES:
        # jax already initialized without the forced device count (e.g.
        # benchmarks.run imported other benches first, or the parent env
        # pinned a smaller count) — re-exec so the XLA flag lands before
        # backend init, then read the cache
        if os.environ.get("_CALIB_SHARDED_CHILD"):
            raise RuntimeError(
                f"re-exec'd child still sees {len(jax.devices())} devices "
                f"< {DEVICES}; check XLA_FLAGS")
        if force or not os.path.exists(result_path(name)):
            args = [sys.executable, "-m", "benchmarks.calib_sharded"]
            if smoke:
                args.append("--smoke")
            if force:
                args.append("--force")
            # strip any caller-pinned force-device flag so the child's
            # __main__ guard re-adds it at 8 (a preset smaller value
            # would otherwise recurse forever)
            flags = " ".join(
                f for f in os.environ.get("XLA_FLAGS", "").split()
                if "--xla_force_host_platform_device_count" not in f)
            subprocess.run(args, check=True, cwd=ROOT, env={
                **os.environ,
                "XLA_FLAGS": flags,
                "_CALIB_SHARDED_CHILD": "1",
                "PYTHONPATH": os.path.join(ROOT, "src") + (
                    os.pathsep + os.environ["PYTHONPATH"]
                    if os.environ.get("PYTHONPATH") else "")})
        with open(result_path(name)) as f:
            out = json.load(f)
        write_bench_json(out["rows"])
        return out

    out = cached(name, lambda: _run_inprocess(smoke), force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    payload = [{"bench": r["bench"], "config": r["config"],
                "tokens_per_s": r["tokens_per_s"],
                "ms_per_batch": r["ms_per_batch"],
                "max_rel_err": r["max_rel_err"]} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + grid (CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    for r in out["rows"]:
        c = r["config"]
        print(f"  {c['path']:16s} b={c['batch']} s={c['seq']} "
              f"n={c['n_batches']} x{c['devices']}dev "
              f"{r['tokens_per_s']:8.0f} tok/s "
              f"(err {r['max_rel_err']:.1e})")
    print(f"  wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
