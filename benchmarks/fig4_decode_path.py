"""Paper Figure 4 analog, decode-path edition: tokens/s and ms/step for
dense vs. D-Rank-compressed models across a batch × cache-length grid.

Two execution paths per cell:
  jnp              — the XLA reference decode (what CPU CI measures; the
                     dense-vs-compressed gap here is the weight-bandwidth
                     effect the paper reports)
  pallas-interpret — the ragged decode-attention + GEMV kernel path run
                     under the Pallas interpreter (CORRECTNESS evidence
                     that the deploy path works end to end; interpreter
                     wall-times are not a perf claim, so only the smallest
                     grid cell runs it)

Emits ``BENCH_decode.json`` at the repo root — one row per cell with the
schema ``{bench, config, tokens_per_s, ms_per_step}`` — in addition to the
usual result cache. ``--smoke`` shrinks the model and grid for CI
(scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.common import ROOT, cached, calib_batches
from repro.configs import get_config
from repro.core import compress as CC
from repro.models import transformer as T
from repro.models.params import set_use_pallas
from repro.serve.engine import Engine, ServeConfig

BENCH_JSON = os.path.join(ROOT, "BENCH_decode.json")

GRID = {"batch": (1, 4, 8), "cache_len": (128, 256, 512), "n_new": 16}
SMOKE_GRID = {"batch": (2,), "cache_len": (32,), "n_new": 8}
RATIO = 0.5
MEASURE_REPS = 3        # best-of-N: single sub-ms decode windows swing
#                         ~2x under this container's scheduler noise and
#                         flake the CI gate (compile is paid once per
#                         Engine, so repeats only re-run the steps)


def _variants(cfg, params, calib):
    ccfg = CC.CompressionConfig(method="drank", ratio=RATIO, group_size=2,
                                beta=0.3)
    lp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib)
    return {"dense": params, f"drank@{RATIO:.0%}": lp}


def _measure(eng, batch, cache_len, n_new, reps: int = MEASURE_REPS):
    warmup = 1
    prompt_len = max(4, cache_len - n_new - warmup - 1)
    best = None
    for _ in range(reps):
        m = eng.measure_decode_throughput(batch=batch,
                                          prompt_len=prompt_len,
                                          n_new=n_new, warmup=warmup)
        if best is None or m["ms_per_step"] < best["ms_per_step"]:
            best = m
    return best


def run(force: bool = False, smoke: bool = False):
    name = "fig4_decode_path" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        cfg = get_config("llama-mini")
        if smoke:
            cfg = cfg.reduced()
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        calib = calib_batches(cfg, n_samples=4, seq_len=32)
        rows = []
        for model, p in _variants(cfg, params, calib).items():
            for b in grid["batch"]:
                for L in grid["cache_len"]:
                    eng = Engine(p, cfg, ServeConfig(batch=b, max_len=L))
                    m = _measure(eng, b, L, grid["n_new"])
                    rows.append({
                        "bench": "fig4_decode_path",
                        "config": {"model": model, "batch": b,
                                   "cache_len": L, "path": "jnp"},
                        **m})
                    print(f"  f4d {model} b={b} L={L} jnp: "
                          f"{m['tokens_per_s']:.0f} tok/s", flush=True)
            # smallest cell again on the Pallas path (interpret mode on
            # CPU): proves the deploy kernels run; timing not comparable
            b, L = grid["batch"][0], grid["cache_len"][0]
            eng = Engine(p, cfg, ServeConfig(batch=b, max_len=L))
            set_use_pallas(True)
            try:
                m = _measure(eng, b, L, min(grid["n_new"], 2))
            finally:
                set_use_pallas(False)
            rows.append({
                "bench": "fig4_decode_path",
                "config": {"model": model, "batch": b, "cache_len": L,
                           "path": "pallas-interpret"},
                **m})
            print(f"  f4d {model} b={b} L={L} pallas-interpret: ok",
                  flush=True)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    payload = [{"bench": r["bench"], "config": r["config"],
                "tokens_per_s": r["tokens_per_s"],
                "ms_per_step": r["ms_per_step"]} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + grid (CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    for r in out["rows"]:
        c = r["config"]
        print(f"  {c['model']:10s} b={c['batch']} L={c['cache_len']:4d} "
              f"{c['path']:16s} {r['tokens_per_s']:8.0f} tok/s "
              f"({r['ms_per_step']:.1f} ms/step)")
    print(f"  wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
