"""Paper Table 2 analog: the GQA grouping pathology.

On a GQA model (slim W_K/W_V), horizontally concatenating n>1 layers for a
shared basis *hurts* (the concatenated matrix's rank exceeds any member's
while the per-matrix retained rank shrinks). The paper's fix (§3.4) is
group_size=1 for GQA models. We reproduce both the pathology (Basis Sharing
PPL rising with n) and the fix (D-Rank with the GQA policy).
"""
from __future__ import annotations

from benchmarks.common import (cached, calib_batches, eval_batches,
                               load_trained, ppl_of)
from repro.core import compress as CC

GROUPS = (1, 2, 4)
RATIO = 0.3


def run(force: bool = False):
    def compute():
        cfg, params, _ = load_trained(run="mini_gqa",
                                      overrides={"n_kv_heads": 2})
        calib = calib_batches(cfg, n_samples=16)
        evalb = eval_batches(cfg, n_batches=4)
        from repro.core.capture import to_list_params
        col = CC.calibrate(to_list_params(params, cfg), cfg, calib)
        rows = [{"method": "original", **ppl_of(params, cfg, evalb)}]
        for n in GROUPS:
            ccfg = CC.CompressionConfig(method="basis", ratio=RATIO,
                                        group_size=n)
            lp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                             collector=col)
            m = ppl_of(lp, cfg, evalb)
            rows.append({"method": "basis", "group": n, **m})
            print(f"  t2 basis n={n}: ppl={m['ppl']:.2f}", flush=True)
        # the paper's GQA policy: drank forces n=1 internally. β=0 control
        # included: on slim GQA K/V matrices the Q/K->V transfer can starve
        # K below viability (the paper's LLaMA-3 K/V are relatively larger).
        for beta in (0.3, 0.0):
            ccfg = CC.CompressionConfig(method="drank", ratio=RATIO,
                                        group_size=4, beta=beta,
                                        gqa_group_one=True)
            lp, plan = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                                collector=col)
            m = ppl_of(lp, cfg, evalb)
            rows.append({"method": f"drank(gqa_n1,b{beta})", "group": 1,
                         **m})
            print(f"  t2 drank gqa-policy beta={beta}: ppl={m['ppl']:.2f}",
                  flush=True)
        return {"ratio": RATIO, "rows": rows}

    return cached("table2_gqa", compute, force)


def main(force: bool = False):
    out = run(force)
    for row in out["rows"]:
        g = row.get("group", "-")
        print(f"  {row['method']:14s} n={g}  ppl={row['ppl']:.3f}")
    return out


if __name__ == "__main__":
    main()
