"""Compression-math throughput: host fp64 loop vs batched jit/device
backend vs randomized SVD (the PR-3 tentpole; DESIGN.md §1.5).

The decomposition stage — Cholesky whitening, whitened SVD, truncation,
refine solve — was the dominant remaining wall-clock of the compression
pipeline once calibration capture moved on device (PR 2). This bench
times that exact math over synthetic group buckets shaped like the
at-scale regimes:

  wide    (d1 << n·d2): shared-basis gate/up groups and fused MoE
          experts — the host fp64 rectangular SVD pays O(d1²·nd2) with a
          LAPACK fp64 constant, while the device path pays the same
          large-dim work as fp32 GEMMs plus one (d1)² eigh. This is the
          headline cell: ``jit-device`` must be ≥10× ``host-eager``.
  square  (d1 ~ n·d2): the exact device path is eigh-bound here, which
          is what the ``randomized`` range-finder row is for — top-k
          factors from GEMMs + a (k+p)² eigh only.

Paths per cell:
  host-eager     core.numerics: per-matrix fp64 cholesky_whitener +
                 whitened_svd + truncate_factors + refine solve (the
                 production host path, unchanged since the seed)
  jit-device     core.numerics_jax.decompose + refine_solve, one batched
                 call per bucket, fp32 (exact: full spectrum)
  randomized     same, rsvd=1 (square cell only)

Every device row records ``max_rel_err`` of the rank-k reconstruction
B·C against the host fp64 oracle (bar: 1e-3) plus ``speedup`` vs the
cell's host row. Timing is best-of-N on both sides — this container's
scheduler noise is well above the effect size (bench_gate compensates
with a loose threshold, but the recorded baseline should be the real
capability, not a noise draw).

The throughput metric is the tokens/s-equivalent for compression math:
``params_per_s`` = dense parameters decomposed per second
(groups · d1 · n·d2 / wall). Emits ``BENCH_compress.json`` with schema
``{bench, config, params_per_s, ms_per_group}``; gated by
``scripts/bench_gate.py --metric params_per_s``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROOT, cached
from repro.core import numerics as num
from repro.core import numerics_jax as numj

BENCH_JSON = os.path.join(ROOT, "BENCH_compress.json")

# (cell, d1, nd2, groups, k, paths)
GRID = [
    ("wide", 448, 8192, 3, 224, ("host-eager", "jit-device")),
    ("square", 512, 1024, 4, 64, ("host-eager", "jit-device",
                                  "randomized")),
]
SMOKE_GRID = [
    ("wide", 448, 8192, 3, 224, ("host-eager", "jit-device")),
    ("square", 512, 1024, 2, 64, ("jit-device", "randomized")),
]
PARITY_TOL = 1e-3
HOST_REPS, DEV_REPS = 3, 5


def _make_cell(rng, d1, nd2, b):
    W = rng.normal(size=(b, d1, nd2))
    G = np.stack([(lambda X: X.T @ X)(rng.normal(size=(2 * d1, d1)))
                  for _ in range(b)])
    G2 = np.stack([(lambda X: X.T @ X)(rng.normal(size=(2 * d1, d1)))
                   for _ in range(b)])
    return W, G, G2


def _host_pipeline(W, G, G2, k):
    """The production host path, per matrix: whiten, SVD, truncate,
    refine solve against the second Gram."""
    outs = []
    for i in range(W.shape[0]):
        wh = num.cholesky_whitener(G[i])
        U, s, Vt = num.whitened_svd(W[i], wh)
        B, C = num.truncate_factors(U, s, Vt, k, wh)
        BtGB = B.T @ G2[i] @ B
        BtGB += 1e-8 * np.trace(BtGB) / max(1, k) * np.eye(k)
        C2 = np.linalg.solve(BtGB, B.T @ G2[i] @ W[i])
        outs.append((B, C, C2))
    return outs


def _device_pipeline(Wj, Gj, G2j, k, rsvd):
    import jax
    sig, B, C = numj.decompose(Wj, gram=Gj, k=k, rsvd=rsvd)
    C2 = numj.refine_solve(B, G2j, Wj)
    return jax.block_until_ready((sig, B, C, C2))


def _best_of(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(force: bool = False, smoke: bool = False):
    name = "compress_path" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        rng = np.random.default_rng(0)
        rows = []
        for cell, d1, nd2, b, k, paths in grid:
            W, G, G2 = _make_cell(rng, d1, nd2, b)
            dense = b * d1 * nd2
            Wj = jnp.asarray(W, dtype=jnp.float32)
            Gj = jnp.asarray(G, dtype=jnp.float32)
            G2j = jnp.asarray(G2, dtype=jnp.float32)
            # fp64 oracle factors for the parity bar (untimed; one matrix
            # is enough — every batch member runs the same compiled code)
            wh = num.cholesky_whitener(G[0])
            U, s, Vt = num.whitened_svd(W[0], wh)
            B0, C0 = num.truncate_factors(U, s, Vt, k, wh)
            R0 = B0 @ C0
            host_pps = None

            def row(path, dt, err=None):
                nonlocal host_pps
                r = {"bench": "compress_path",
                     "config": {"path": path, "cell": cell, "d1": d1,
                                "nd2": nd2, "groups": b, "k": k},
                     "params_per_s": dense / dt,
                     "ms_per_group": dt / b * 1000.0}
                if err is not None:
                    r["max_rel_err"] = err
                if path == "host-eager":
                    host_pps = r["params_per_s"]
                elif host_pps is not None:
                    r["speedup"] = r["params_per_s"] / host_pps
                rows.append(r)
                extra = "".join(
                    [f" rel err {err:.1e}" if err is not None else "",
                     f" {r.get('speedup', 0):.1f}x" if "speedup" in r
                     else ""])
                print(f"  compress {cell:7s} {path:12s}: "
                      f"{r['params_per_s']:.3g} params/s "
                      f"({r['ms_per_group']:.0f} ms/group{extra})",
                      flush=True)

            if "host-eager" in paths:
                dt, _ = _best_of(lambda: _host_pipeline(W, G, G2, k),
                                 HOST_REPS)
                row("host-eager", dt)
            exact_err = None
            for path, rsvd in (("jit-device", 0), ("randomized", 1)):
                if path not in paths:
                    continue
                _device_pipeline(Wj, Gj, G2j, k, rsvd)     # compile
                dt, out = _best_of(
                    lambda: _device_pipeline(Wj, Gj, G2j, k, rsvd),
                    DEV_REPS)
                R1 = (np.asarray(out[1][0], dtype=np.float64)
                      @ np.asarray(out[2][0], dtype=np.float64))
                # exact path: elementwise parity vs the fp64 oracle.
                # randomized: its subspace is approximate by design, so
                # compare whitened reconstruction ERROR against exact's
                if rsvd == 0:
                    err = float(np.abs(R1 - R0).max() / np.abs(R0).max())
                    exact_err = np.linalg.norm(wh.apply(W[0] - R1))
                    assert err < PARITY_TOL, \
                        f"device factors diverged: {err:.2e}"
                else:
                    e_rs = np.linalg.norm(wh.apply(W[0] - R1))
                    ref = exact_err if exact_err is not None else \
                        np.linalg.norm(wh.apply(W[0] - R0))
                    err = float(e_rs / max(ref, 1e-12) - 1.0)
                    assert err < 0.10, \
                        f"rsvd reconstruction off by {err:.1%} vs exact"
                row(path, dt, err)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    payload = [{"bench": r["bench"], "config": r["config"],
                "params_per_s": r["params_per_s"],
                "ms_per_group": r["ms_per_group"],
                **{kk: r[kk] for kk in ("max_rel_err", "speedup")
                   if kk in r}} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller grid (CI)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    for r in out["rows"]:
        c = r["config"]
        print(f"  {c['cell']:7s} {c['path']:12s} d1={c['d1']} "
              f"nd2={c['nd2']} g={c['groups']} k={c['k']} "
              f"{r['params_per_s']:.3g} params/s")
    print(f"  wrote {BENCH_JSON}")
    return out


if __name__ == "__main__":
    main()
