"""Paper Table 5 analog: PPL of D-Rank as a function of the attention
rebalance ratio β and the group size n, vs the Basis Sharing baseline.

Claim reproduced: a moderate β (≈0.3–0.4) beats both β=0 and the uniform
Basis Sharing allocation.
"""
from __future__ import annotations

from benchmarks.common import (cached, calib_batches, eval_batches,
                               load_trained, ppl_of)
from repro.core import compress as CC

BETAS = (0.0, 0.2, 0.3, 0.4, 0.5)
GROUPS = (2, 4)
RATIO = 0.3


def run(force: bool = False):
    def compute():
        cfg, params, _ = load_trained()
        calib = calib_batches(cfg, n_samples=16)
        evalb = eval_batches(cfg, n_batches=4)
        from repro.core.capture import to_list_params
        col = CC.calibrate(to_list_params(params, cfg), cfg, calib)
        rows = []
        for n in GROUPS:
            bb = CC.CompressionConfig(method="basis", ratio=RATIO,
                                      group_size=n)
            blp, _ = CC.build_plan_and_params(params, cfg, bb, calib,
                                              collector=col)
            rows.append({"method": "basis", "group": n, "beta": None,
                         **ppl_of(blp, cfg, evalb)})
            for beta in BETAS:
                ccfg = CC.CompressionConfig(method="drank", ratio=RATIO,
                                            group_size=n, beta=beta)
                lp, _ = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                                 collector=col)
                m = ppl_of(lp, cfg, evalb)
                rows.append({"method": "drank", "group": n, "beta": beta,
                             **m})
                print(f"  t5 n={n} beta={beta}: ppl={m['ppl']:.2f}",
                      flush=True)
        return {"ratio": RATIO, "rows": rows}

    return cached("table5_beta", compute, force)


def main(force: bool = False):
    out = run(force)
    print(f"beta sweep @ {out['ratio']:.0%} compression")
    for row in out["rows"]:
        tag = f"beta={row['beta']}" if row["beta"] is not None else "basis"
        print(f"  n={row['group']} {tag:10s} ppl={row['ppl']:.3f}")
    return out


if __name__ == "__main__":
    main()
