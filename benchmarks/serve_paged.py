"""Paged KV cache benchmark (ISSUE 9 / DESIGN.md §5.7): peak KV memory
and decode throughput of the continuous batcher under a mixed workload —
contiguous per-slot pool vs the block-table paged pool, with and without
prefix reuse.

The contiguous pool pins ``batch x max_len`` KV for every slot from boot,
whatever the requests actually use; the paged pool pins only the blocks
live requests (and published prefix entries) hold, so a realistic mix of
short chats, a few long-context requests and a shared-header group needs
a fraction of the memory AT PEAK. Tokens are asserted identical across
all three modes before any number is reported — the memory win is only
interesting if the outputs are bit-for-bit the oracle's.

Emits ``BENCH_serve_paged.json`` — one row per mode with the schema
``{bench, config, tokens_per_s, ms_per_step, peak_kv_mib}`` — alongside
the usual result cache. ``--smoke`` shrinks the model and workload for CI
(scripts/ci.sh gates tokens_per_s against a committed baseline and
asserts the paged peak stays below the contiguous one).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import ROOT, cached
from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig

BENCH_JSON = os.path.join(ROOT, "BENCH_serve_paged.json")

GRID = {"slots": 4, "max_len": 256, "kv_block": 16, "n_new": 16,
        "short": 10, "prompt_short": 16, "long": 2, "prompt_long": 72,
        "shared": 4, "header": 64, "tail": 8}
SMOKE_GRID = {"slots": 2, "max_len": 128, "kv_block": 16, "n_new": 6,
              "short": 4, "prompt_short": 8, "long": 1, "prompt_long": 40,
              "shared": 3, "header": 32, "tail": 4}
MEASURE_REPS = 3        # best-of-N: sub-ms step windows swing ~2x under
#                         this container's scheduler noise (see fig4)

MODES = ("contiguous", "paged", "paged+prefix")


def _scfg(grid, mode):
    return ServeConfig(
        batch=grid["slots"], max_len=grid["max_len"],
        kv_block=0 if mode == "contiguous" else grid["kv_block"],
        prefix_cache=(mode == "paged+prefix"))


def _workload(grid, vocab, seed=0, rid_base=0):
    """Mixed mix: mostly short chats, a couple of long-context requests,
    and a shared-header group (same header tokens EVERY drain at this
    seed, distinct tails) submitted last so earlier rounds publish the
    header blocks the rest reuse."""
    rng = np.random.default_rng(seed)
    reqs, rid = [], rid_base

    def add(tokens):
        nonlocal rid
        reqs.append(Request(rid=rid, n_new=grid["n_new"], tokens=tokens))
        rid += 1

    for _ in range(grid["short"]):
        add(rng.integers(0, vocab, size=(grid["prompt_short"],),
                         dtype=np.int32))
    for _ in range(grid["long"]):
        add(rng.integers(0, vocab, size=(grid["prompt_long"],),
                         dtype=np.int32))
    header = np.random.default_rng(seed + 999).integers(
        0, vocab, size=(grid["header"],), dtype=np.int32)
    for _ in range(grid["shared"]):
        add(np.concatenate([header, rng.integers(
            0, vocab, size=(grid["tail"],), dtype=np.int32)]))
    return reqs


def _kv_bytes_contiguous(cb) -> int:
    """The contiguous pool's cost is its full allocation."""
    return sum(leaf.nbytes for leaf in jax.tree.leaves(cb.cache["runs"]))


def _kv_bytes_paged_peak(cb) -> int:
    """Peak blocks ever held x bytes per physical block (arena leaves are
    (n_layers, P, block, KV, hd); the null block is never allocated)."""
    per_block = sum(leaf.nbytes // leaf.shape[1]
                    for leaf in jax.tree.leaves(cb.cache["runs"]))
    return cb.pool.peak_in_use * per_block


def _drain_once(cb, cfg, grid, rid_base):
    work = _workload(grid, cfg.vocab_size, rid_base=rid_base)
    steps0 = cb.metrics()["steps"]
    for r in work:
        cb.submit(r)
    t0 = time.perf_counter()
    res = cb.run_until_drained()
    dt = time.perf_counter() - t0
    assert res.status == "drained", res.status
    steps = cb.metrics()["steps"] - steps0
    toks = sum(len(r.out) for r in work)
    return ({r.rid - rid_base: list(r.out) for r in work},
            {"tokens_per_s": toks / dt,
             "ms_per_step": dt / max(1, steps) * 1e3})


def _measure(mode, params, cfg, grid, reps=MEASURE_REPS):
    """One batcher per mode: a warm drain pays every compile, then
    best-of-N timed drains of the identical workload shape."""
    cb = ContinuousBatcher(params, cfg, _scfg(grid, mode))
    warm = _workload(grid, cfg.vocab_size, seed=1, rid_base=90_000)
    for r in warm:
        cb.submit(r)
    res = cb.run_until_drained()
    assert res.status == "drained", res.status
    best, tokens = None, None
    for rep in range(reps):
        toks, m = _drain_once(cb, cfg, grid, rid_base=rep * 1000)
        if tokens is None:
            tokens = toks
        else:
            assert toks == tokens    # repeated drains are deterministic
        if best is None or m["ms_per_step"] < best["ms_per_step"]:
            best = m
    if mode == "contiguous":
        best["peak_kv_mib"] = _kv_bytes_contiguous(cb) / 2**20
    else:
        best["peak_kv_mib"] = _kv_bytes_paged_peak(cb) / 2**20
    return cb, tokens, best


def run(force: bool = False, smoke: bool = False):
    name = "serve_paged" + ("_smoke" if smoke else "")
    grid = SMOKE_GRID if smoke else GRID

    def compute():
        cfg = get_config("llama-mini")
        if smoke:
            cfg = cfg.reduced()
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        rows, oracle = [], None
        for mode in MODES:
            cb, tokens, m = _measure(mode, params, cfg, grid)
            if oracle is None:
                oracle = tokens
            else:
                # the paged pool must be invisible in the output stream
                assert tokens == oracle, f"{mode} diverged from contiguous"
            m["peak_kv_mib"] = round(m["peak_kv_mib"], 3)
            row = {"bench": "serve_paged", "config": {"mode": mode}, **m}
            if mode == "paged+prefix":
                mm = cb.metrics()
                row["prefix_hits"] = mm["prefix_hits"]
                row["cow_forks"] = mm["cow_forks"]
            rows.append(row)
            print(f"  spg {mode}: {m['tokens_per_s']:.0f} tok/s "
                  f"peak_kv={m['peak_kv_mib']:.2f}MiB", flush=True)
        contig = rows[0]["peak_kv_mib"]
        for r in rows[1:]:
            assert r["peak_kv_mib"] < contig, \
                (r["config"]["mode"], r["peak_kv_mib"], contig)
        return {"rows": rows}

    out = cached(name, compute, force)
    write_bench_json(out["rows"])
    return out


def write_bench_json(rows, path: str = BENCH_JSON) -> str:
    keep = ("bench", "config", "tokens_per_s", "ms_per_step",
            "peak_kv_mib", "prefix_hits", "cow_forks")
    payload = [{k: r[k] for k in keep if k in r} for r in rows]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    out = run(force=args.force, smoke=args.smoke)
    print(json.dumps(out["rows"], indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
