"""Quickstart: train a small LLaMA-family model on the synthetic corpus,
compress it with D-Rank and every baseline, and compare perplexity.

    PYTHONPATH=src python examples/quickstart.py

This is the paper's core loop end-to-end in ~3 minutes of CPU time:
calibration Grams -> whitened grouped SVD -> effective-rank Lagrange
allocation -> β rebalance -> factorized deploy params.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import compress as CC
from repro.data.synthetic import DataConfig, ShardedLoader, \
    calibration_batches
from repro.optim.adamw import OptimizerConfig
from repro.train import step as TS


def main():
    # -- a tiny model so the whole script stays fast -------------------------
    cfg = get_config("llama-mini").replace(n_layers=4, d_model=128,
                                           n_heads=4, n_kv_heads=4,
                                           head_dim=32, d_ff=344,
                                           vocab_size=1024)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    loader = ShardedLoader(dcfg)

    print("== training 150 steps ==")
    state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    tcfg = TS.TrainConfig(optimizer=OptimizerConfig(
        lr=2e-3, warmup_steps=20, total_steps=150))
    step_fn = jax.jit(TS.make_train_step(cfg, tcfg), donate_argnums=0)
    for s in range(150):
        batch = {k: jnp.asarray(v) for k, v in loader.batch(s).items()}
        state, m = step_fn(state, batch)
        if s % 50 == 0:
            print(f"  step {s}: loss {float(m['loss']):.3f}")
    params = state.params

    # -- evaluation set ------------------------------------------------------
    evalb = [{k: jnp.asarray(v) for k, v in loader.batch(10_000 + i).items()}
             for i in range(3)]
    base = TS.evaluate_ppl(params, cfg, evalb)
    print(f"dense ppl: {base['ppl']:.2f}")

    # -- calibrate once, compress six ways -----------------------------------
    calib = [{"tokens": jnp.asarray(b["tokens"])}
             for b in calibration_batches(dcfg, 16, 8)]
    from repro.core.capture import to_list_params
    col = CC.calibrate(to_list_params(params, cfg), cfg, calib)

    print("== 30% compression, all methods ==")
    for method in CC.METHODS:
        ccfg = CC.CompressionConfig(method=method, ratio=0.3, group_size=2,
                                    beta=0.3)
        lp, plan = CC.build_plan_and_params(params, cfg, ccfg, calib,
                                            collector=col)
        m = TS.evaluate_ppl(lp, cfg, evalb)
        print(f"  {method:7s}: ppl {m['ppl']:8.2f} "
              f"(removed {plan.summary['achieved_ratio']:.1%})")


if __name__ == "__main__":
    main()
