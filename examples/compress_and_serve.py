"""Compress-then-serve: the deployment story. Loads (or quickly trains) a
model, applies D-Rank at 30% (calibration Grams captured by the jit/device
streaming path), persists the compressed artifact, boots a SECOND engine
straight from the checkpoint (no re-compression) and checks it decodes
token-identically — then serves a batch of requests through the
continuous-batching engine, comparing dense vs compressed decode
throughput (paper Fig. 4's phenomenon).

    PYTHONPATH=src python examples/compress_and_serve.py
"""
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import compress as CC
from repro.data.synthetic import DataConfig, ShardedLoader, \
    calibration_batches
from repro.optim.adamw import OptimizerConfig
from repro.serve.engine import ContinuousBatcher, Engine, Request, \
    ServeConfig
from repro.train import step as TS


def main():
    cfg = get_config("llama-mini").replace(n_layers=4, d_model=128,
                                           n_heads=4, n_kv_heads=4,
                                           head_dim=32, d_ff=344,
                                           vocab_size=1024)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    loader = ShardedLoader(dcfg)
    state, _ = TS.init_train_state(cfg, jax.random.PRNGKey(0))
    tcfg = TS.TrainConfig(optimizer=OptimizerConfig(
        lr=2e-3, warmup_steps=10, total_steps=80))
    step_fn = jax.jit(TS.make_train_step(cfg, tcfg), donate_argnums=0)
    for s in range(80):
        state, _ = step_fn(state, {k: jnp.asarray(v)
                                   for k, v in loader.batch(s).items()})
    params = state.params

    calib = [{"tokens": jnp.asarray(b["tokens"])}
             for b in calibration_batches(dcfg, 8, 8)]
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                beta=0.3)
    comp, plan = CC.build_plan_and_params(params, cfg, ccfg, calib)
    print(f"compressed: {plan.summary['achieved_ratio']:.1%} of "
          f"compressible params removed")

    # persist + boot from the artifact: the deploy path never re-compresses
    ckpt_dir = tempfile.mkdtemp(prefix="drank_ckpt_")
    CC.save_plan(ckpt_dir, comp, plan, cfg)
    eng_ckpt = Engine.from_compressed(ckpt_dir, cfg, ServeConfig())
    prompts = np.arange(24, dtype=np.int32).reshape(4, 6) % cfg.vocab_size
    same = (Engine(comp, cfg, ServeConfig()).generate(prompts, 16)
            == eng_ckpt.generate(prompts, 16)).all()
    print(f"checkpoint round-trip: saved to {ckpt_dir}, booted engine "
          f"decodes token-identical: {bool(same)}")
    assert same

    for name, p in (("dense", params), ("drank-30%", comp)):
        eng = Engine(p, cfg, ServeConfig())
        m = eng.measure_decode_throughput(batch=4, prompt_len=16, n_new=32)
        print(f"  {name:10s}: {m['tokens_per_s']:7.0f} tok/s "
              f"({m['ms_per_step']:.1f} ms/decode-step)")

    print("== continuous batching, 6 requests on 3 slots (ckpt boot) ==")
    cb = ContinuousBatcher.from_compressed(
        ckpt_dir, cfg, ServeConfig(batch=3, max_len=96))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(6):
        cb.submit(Request(rid=i, tokens=rng.integers(
            0, cfg.vocab_size, size=(8 + 2 * i,), dtype=np.int32),
            n_new=16))
    done = cb.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"  served {len(done)} requests, "
          f"{sum(len(r.out) for r in done)} tokens in {dt:.1f}s")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
