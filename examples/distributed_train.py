"""Distributed training walkthrough: the SAME train step as the single-
device path, jitted against a (data, model) mesh built over this host's
devices — sharded params/optimizer (FSDP+TP), elastic deterministic data
shards, checkpoint + RESHARD-on-restore, and the PowerSGD cross-pod
gradient-compression transform.

Run with fake devices to see real sharding on CPU:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_train.py
"""
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import DataConfig, ShardedLoader
from repro.dist import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.optim import powersgd as PS
from repro.optim.adamw import OptimizerConfig, adamw_init
from repro.train import step as TS


def main():
    n = len(jax.devices())
    data, model = (4, 2) if n >= 8 else (max(1, n), 1)
    print(f"{n} devices -> mesh (data={data}, model={model})")
    mesh = make_host_mesh(data, model)

    cfg = get_config("llama-mini").replace(vocab_size=1024, n_layers=4)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=8)
    with mesh, SH.use_rules({}, mesh=mesh):
        state, specs = TS.init_train_state(cfg, jax.random.PRNGKey(0))
        p_sh = SH.shardings_for_tree(state.params, specs, mesh)
        opt_sh = TS.AdamWState(
            step=jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec()),
            mu=p_sh, nu=p_sh)
        st_sh = TS.TrainState(params=p_sh, opt=opt_sh)
        state = jax.device_put(state, st_sh)
        tcfg = TS.TrainConfig(optimizer=OptimizerConfig(
            lr=2e-3, warmup_steps=10, total_steps=60))
        step_fn = jax.jit(TS.make_train_step(cfg, tcfg),
                          in_shardings=(st_sh, None),
                          out_shardings=(st_sh, None),
                          donate_argnums=0)
        loader = ShardedLoader(dcfg)   # single host reads all shards here
        for s in range(30):
            batch = {k: jnp.asarray(v) for k, v in loader.batch(s).items()}
            state, m = step_fn(state, batch)
            if s % 10 == 0:
                print(f"  step {s}: loss {float(m['loss']):.3f}")
        w = state.params["decoder"]["run0"]["attn"]["wq"]["w"]
        print("  wq sharding:", w.sharding.spec)

    # -- cross-pod gradient compression (PowerSGD + error feedback) ---------
    print("== PowerSGD gradient compression demo ==")
    grads = jax.tree.map(lambda p: jnp.ones_like(p, dtype=jnp.float32) * 0.1,
                         state.params)
    pcfg = PS.PowerSGDConfig(rank=4, min_dim=64)
    pstate = PS.init_state(grads, pcfg)
    _, _, stats = PS.compress_decompress(grads, pstate, pcfg)
    print(f"  cross-pod byte reduction: {stats['byte_reduction']:.1f}x "
          f"({stats['dense_bytes'] / 1e6:.1f} MB -> "
          f"{stats['compressed_bytes'] / 1e6:.1f} MB per step)")


if __name__ == "__main__":
    main()
