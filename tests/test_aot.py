"""ISSUE 7 AOT coverage: the persistent-executable registry must only
ever change COST, never results. Oracle: the traced registry's token
stream. Asserts the boot contract (second boot performs zero compiles),
fingerprint isolation (a different artifact never replays a cached
executable), and the corruption fallback ladder.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import compress as CC
from repro.models import transformer as T
from repro.serve import aot as aotlib
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rank_multiple=1)
SCFG = ServeConfig(batch=2, max_len=32)


@pytest.fixture(scope="module")
def comp():
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    c, _ = CC.build_plan_and_params(
        params, CFG, CC.CompressionConfig(ratio=0.4), calib)
    return c


def _workload(n=4, n_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, n_new=n_new,
                    tokens=rng.integers(0, CFG.vocab_size, size=(7,),
                                        dtype=np.int32))
            for i in range(n)]


def _drain(params, registry=None):
    cb = ContinuousBatcher(params, CFG, SCFG, executables=registry)
    cb.warm_executables()
    reqs = _workload()
    for r in reqs:
        cb.submit(r)
    res = cb.run_until_drained()
    assert res.status == "drained"
    return {r.rid: list(r.out) for r in res}, cb.stats


def _registry(comp, cache_dir, fingerprint=None):
    return aotlib.AotRegistry(
        CFG, SCFG,
        fingerprint or aotlib.live_fingerprint(comp, CFG),
        cache_dir=str(cache_dir))


def test_aot_boot_token_identical_and_second_boot_compile_free(
        tmp_path, comp):
    oracle, tstats = _drain(comp)                       # traced reference
    assert tstats["decode_retraces"] == 1

    cold, s1 = _drain(comp, _registry(comp, tmp_path))  # boot 1: compiles
    assert cold == oracle
    assert s1["aot_compiles"] > 0 and s1["aot_cache_hits"] == 0
    assert s1["decode_retraces"] == 0                   # nothing traced lazily

    warm, s2 = _drain(comp, _registry(comp, tmp_path))  # boot 2: cache only
    assert warm == oracle
    assert s2["aot_compiles"] == 0, s2
    assert s2["aot_cache_hits"] > 0
    assert s2["aot_fallbacks"] == 0 and s2["aot_deser_failures"] == 0


def test_fingerprint_mismatch_recompiles_not_replays(tmp_path, comp):
    _drain(comp, _registry(comp, tmp_path))             # populate cache
    # same shapes, different artifact identity: the cache must MISS —
    # replaying another artifact's executable would be silently wrong
    # if shapes ever coincided across incompatible artifacts
    other, s = _drain(comp, _registry(comp, tmp_path,
                                      fingerprint="sha256:deadbeef"))
    assert s["aot_compiles"] > 0
    assert s["aot_cache_hits"] == 0
    oracle, _ = _drain(comp)
    assert other == oracle


def test_corrupt_cache_entry_falls_back_to_compile(tmp_path, comp):
    reg = _registry(comp, tmp_path)
    _drain(comp, reg)                                   # populate cache
    for key in reg.cache.keys():                        # torch every entry
        with open(reg.cache.path(key), "wb") as f:
            f.write(b"not an executable")
    redo, s = _drain(comp, _registry(comp, tmp_path))
    assert s["aot_deser_failures"] > 0
    assert s["aot_compiles"] == s["aot_deser_failures"]  # each re-made once
    oracle, _ = _drain(comp)
    assert redo == oracle


def test_cache_key_separates_roles_variants_and_config(comp):
    fp = aotlib.live_fingerprint(comp, CFG)
    sig = "sig"
    k = aotlib.cache_key(fp, "decode", (0,), sig, SCFG, CFG)
    assert k != aotlib.cache_key(fp, "prefill", (0,), sig, SCFG, CFG)
    assert k != aotlib.cache_key(fp, "decode", (1,), sig, SCFG, CFG)
    assert k != aotlib.cache_key(fp, "decode", (0,), sig,
                                 ServeConfig(batch=4, max_len=32), CFG)
    assert k != aotlib.cache_key("sha256:other", "decode", (0,), sig,
                                 SCFG, CFG)
    assert k == aotlib.cache_key(fp, "decode", (0,), sig, SCFG, CFG)
