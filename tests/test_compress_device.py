"""PR-3 tentpole coverage: on-device compression math (numerics_jax) vs
the host fp64 oracle (numerics), per stage and at plan level.

Tolerance tiers (fp32 device math vs fp64 host math):

  stage                           bar        why
  ------------------------------  ---------  ---------------------------
  damped Cholesky (L Lᵀ = G+τI)   1e-5 rel   one factorization, fp32
  whitened spectrum σ             1e-5 rel   eigh of an explicit Gram
  rank-k factors (B·C product)    1e-4 rel   truncation boundary mixing
  refine solve C*                 2e-4 rel   normal equations + solve
  randomized SVD                  ≤5% extra whitened reconstruction
                                  error vs the exact rank-k optimum

Plan level (the acceptance bar): ``build_plan_and_params(device=True)``
must produce IDENTICAL integer rank allocations and token-identical
greedy serve output vs the host path at default tolerances.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import compress as CC
from repro.core import numerics as num
from repro.core import numerics_jax as numj
from repro.core.capture import (StreamingCalibrator, streaming_calibrate,
                                to_list_params)
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig

SIG_TOL = 1e-5
FACTOR_TOL = 1e-4
REFINE_TOL = 2e-4

# tiny LLaMA-ish configs; n_layers=3 with group_size=2 forces a RAGGED
# final group (n=1) in every groupable type
CFG_MHA = get_config("llama-mini").replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, rank_multiple=4)
CFG_GQA = CFG_MHA.replace(n_kv_heads=2)
CFG_BF16 = CFG_MHA.replace(param_dtype="bfloat16")


def _batches(cfg, n=2, batch=2, seq=32, seed=7):
    key = jax.random.PRNGKey(seed)
    return [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                          (batch, seq), 0, cfg.vocab_size)}
            for i in range(n)]


def _rand_spd(rng, d, rows=None):
    X = rng.normal(size=(rows or 2 * d, d))
    return X.T @ X


def _host_factors(W, G, k, damp=1e-6):
    wh = num.cholesky_whitener(G, damp)
    U, s, Vt = num.whitened_svd(W, wh)
    B, C = num.truncate_factors(U, s, Vt, k, wh)
    return s, B, C, wh


# ---------------------------------------------------------------------------
# Stage parity on synthetic matrices (every shape regime)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d1,nd2", [(48, 96), (96, 48), (64, 64),
                                    (32, 160)])
def test_decompose_gram_parity(d1, nd2):
    rng = np.random.default_rng(0)
    b, k = 3, min(d1, nd2) // 3
    W = rng.normal(size=(b, d1, nd2))
    G = np.stack([_rand_spd(rng, d1) for _ in range(b)])
    sig, B, C = numj.decompose(W, gram=G, k=k)
    sig = np.asarray(sig, dtype=np.float64)
    for i in range(b):
        s0, B0, C0, wh = _host_factors(W[i], G[i], k)
        assert np.abs(sig[i][:len(s0)] - s0).max() / s0.max() < SIG_TOL
        R0 = B0 @ C0
        R1 = np.asarray(B[i], np.float64) @ np.asarray(C[i], np.float64)
        assert np.abs(R1 - R0).max() / np.abs(R0).max() < FACTOR_TOL
        # whitened reconstruction error matches the Eckart-Young optimum
        e0 = np.linalg.norm(wh.apply(W[i] - R0))
        e1 = np.linalg.norm(wh.apply(W[i] - R1))
        assert e1 <= e0 * (1 + 1e-4) + 1e-9


def test_decompose_full_rank_is_exact():
    rng = np.random.default_rng(1)
    W = rng.normal(size=(2, 40, 64))
    G = np.stack([_rand_spd(rng, 40) for _ in range(2)])
    _, B, C = numj.decompose(W, gram=G, k=40)
    R = np.asarray(B, np.float64) @ np.asarray(C, np.float64)
    assert np.abs(R - W).max() < 1e-3 * np.abs(W).max()


@pytest.mark.parametrize("mode", ["diag", "identity", "factor"])
def test_decompose_other_whiteners(mode):
    rng = np.random.default_rng(2)
    b, d1, nd2, k = 2, 48, 80, 12
    W = rng.normal(size=(b, d1, nd2))
    if mode == "diag":
        scale = np.abs(rng.normal(size=(b, d1))) + 0.5
        sig, B, C = numj.decompose(W, diag=scale, k=k)
        whs = [num.diag_whitener(scale[i]) for i in range(b)]
    elif mode == "identity":
        sig, B, C = numj.decompose(W, k=k)
        whs = [num.identity_whitener() for _ in range(b)]
    else:
        G = np.stack([_rand_spd(rng, d1) for _ in range(b)])
        R = np.stack([np.linalg.cholesky(G[i]).T for i in range(b)])
        sig, B, C = numj.decompose(W, factor=R, k=k)
        whs = [num.whitener_from_factor(R[i]) for i in range(b)]
    for i in range(b):
        U, s, Vt = num.whitened_svd(W[i], whs[i])
        B0, C0 = num.truncate_factors(U, s, Vt, k, whs[i])
        R0 = B0 @ C0
        R1 = np.asarray(B[i], np.float64) @ np.asarray(C[i], np.float64)
        assert np.abs(R1 - R0).max() / np.abs(R0).max() < FACTOR_TOL, mode


def test_cholesky_escalate_matches_host():
    rng = np.random.default_rng(3)
    d = 24
    # one healthy Gram, one rank-deficient (forces escalation), one zero
    G = np.stack([_rand_spd(rng, d),
                  _rand_spd(rng, d, rows=d // 4),
                  np.zeros((d, d))])
    L, tau = numj.cholesky_escalate(jnp.asarray(G, jnp.float32))
    L = np.asarray(L, dtype=np.float64)
    tau = np.asarray(tau, dtype=np.float64)
    assert np.isfinite(L).all()
    for i in range(3):
        # same damping start as the host oracle; reconstruction holds
        ref = num.cholesky_whitener(G[i])
        got = L[i] @ L[i].T
        want = G[i] + tau[i] * np.eye(d)
        assert np.abs(got - want).max() <= 1e-5 * max(want.max(), 1e-9)
        # host S and device Lᵀ agree on the healthy matrix
        if i == 0:
            assert np.abs(L[i].T - ref.S).max() / np.abs(ref.S).max() < 1e-4


def test_rsvd_close_to_exact():
    rng = np.random.default_rng(4)
    b, d1, nd2, k = 2, 96, 192, 16
    # decaying spectrum (the regime rsvd is for)
    W = np.einsum("bik,bkj->bij", rng.normal(size=(b, d1, 24)),
                  rng.normal(size=(b, 24, nd2)))
    W += 0.01 * rng.normal(size=(b, d1, nd2))
    G = np.stack([_rand_spd(rng, d1) for _ in range(b)])
    sig, B, C = numj.decompose(W, gram=G, k=k, rsvd=1)
    # full-length spectrum: top-(k+oversample) estimated individually,
    # the rest a flat tail carrying the exact truncated energy
    assert np.asarray(sig).shape[1] == min(d1, nd2)
    for i in range(b):
        _, B0, C0, wh = _host_factors(W[i], G[i], k)
        e0 = np.linalg.norm(wh.apply(W[i] - B0 @ C0))
        R1 = np.asarray(B[i], np.float64) @ np.asarray(C[i], np.float64)
        e1 = np.linalg.norm(wh.apply(W[i] - R1))
        assert e1 <= e0 * 1.05 + 1e-9


def test_rsvd_tail_energy_keeps_reff_calibrated():
    """The flat synthetic tail restores the truncated energy exactly
    (trace identity), so total energy matches the exact spectrum and
    effective rank stays close to the full-spectrum oracle instead of
    collapsing to the top-l cutoff."""
    rng = np.random.default_rng(11)
    b, d1, nd2, k = 2, 96, 192, 16
    W = np.einsum("bik,bkj->bij", rng.normal(size=(b, d1, 48)),
                  rng.normal(size=(b, 48, nd2)))
    W += 0.05 * rng.normal(size=(b, d1, nd2))
    G = np.stack([_rand_spd(rng, d1) for _ in range(b)])
    sig_x, _, _ = numj.decompose(W, gram=G, k=k)            # exact
    sig_r, _, _ = numj.decompose(W, gram=G, k=k, rsvd=1)    # randomized
    sig_x = np.asarray(sig_x, np.float64)
    sig_r = np.asarray(sig_r, np.float64)
    assert sig_r.shape == sig_x.shape
    # the synthetic tail may not break the allocators' ordering
    # invariant, even where the sketch underestimated sigma_l
    assert (np.diff(sig_r, axis=1) <= 1e-6 * sig_r[:, :1]).all()
    for i in range(b):
        # total energy exact to fp32 roundoff
        ex, er = (sig_x[i] ** 2).sum(), (sig_r[i] ** 2).sum()
        assert abs(er - ex) / ex < 1e-4, (i, er, ex)
        # knee spectrum (rank-48 signal + noise) is adversarial for any
        # tail extrapolation: accept ~10% but demand a real improvement
        # over the pre-correction truncated spectrum
        rx = num.effective_rank(sig_x[i])
        rr = num.effective_rank(sig_r[i])
        assert abs(rr - rx) / rx < 0.12, (i, rr, rx)
        r_trunc = num.effective_rank(sig_r[i][:k + 8])
        assert abs(rr - rx) < abs(r_trunc - rx)


def test_rsvd_tail_energy_smooth_spectrum_tight():
    """On smooth decaying spectra — the regime rsvd_threshold targets —
    the geometric tail tracks the oracle reff to ~2%."""
    rng = np.random.default_rng(5)
    d1, nd2, k = 96, 192, 16
    U = np.linalg.qr(rng.normal(size=(d1, d1)))[0]
    V = np.linalg.qr(rng.normal(size=(nd2, d1)))[0]
    for s in ((np.arange(1, d1 + 1, dtype=float)) ** -1.2,
              np.exp(-0.08 * np.arange(d1))):
        W = (U @ np.diag(s) @ V.T)[None]
        sig_x, _, _ = numj.decompose(W, k=k)
        sig_r, _, _ = numj.decompose(W, k=k, rsvd=1)
        rx = num.effective_rank(np.asarray(sig_x, np.float64)[0])
        rr = num.effective_rank(np.asarray(sig_r, np.float64)[0])
        assert abs(rr - rx) / rx < 0.02, (rr, rx)


def test_refine_solve_parity():
    rng = np.random.default_rng(5)
    b, d, k, m = 3, 48, 10, 72
    B = rng.normal(size=(b, d, k))
    G = np.stack([_rand_spd(rng, d, rows=128) for _ in range(b)])
    W = rng.normal(size=(b, d, m))
    C = np.asarray(numj.refine_solve(
        jnp.asarray(B, jnp.float32), jnp.asarray(G, jnp.float32),
        jnp.asarray(W, jnp.float32)), dtype=np.float64)
    for i in range(b):
        BtGB = B[i].T @ G[i] @ B[i]
        BtGB += 1e-8 * np.trace(BtGB) / k * np.eye(k)
        C0 = np.linalg.solve(BtGB, B[i].T @ G[i] @ W[i])
        assert np.abs(C[i] - C0).max() / np.abs(C0).max() < REFINE_TOL


def test_combine_factors_matches_gram_sum():
    rng = np.random.default_rng(6)
    b, n, d = 2, 3, 20
    Gs = np.stack([[_rand_spd(rng, d) for _ in range(n)]
                   for _ in range(b)])
    Rs = np.linalg.cholesky(Gs).swapaxes(-1, -2)
    R = np.asarray(numj.combine_factors(jnp.asarray(Rs, jnp.float32)),
                   dtype=np.float64)
    for i in range(b):
        want = Gs[i].sum(0)
        got = R[i].T @ R[i]
        assert np.abs(got - want).max() / np.abs(want).max() < 1e-5


# ---------------------------------------------------------------------------
# Plan-level parity: identical ranks, token-identical serving
# ---------------------------------------------------------------------------
def _plan_parity(cfg, method="drank", refine=False, device_kwargs=None,
                 seed=0, beta=0.3, **ccfg_kw):
    params, _ = T.init_model(cfg, jax.random.PRNGKey(seed))
    batches = _batches(cfg)
    ccfg = CC.CompressionConfig(method=method, ratio=0.3, group_size=2,
                                beta=beta, refine=refine, **ccfg_kw)
    lp_h, plan_h = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False)
    lp_d, plan_d = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False, device=True,
                                            **(device_kwargs or {}))
    ks_h = {g.gid: g.k for g in plan_h.groups}
    ks_d = {g.gid: g.k for g in plan_d.groups}
    assert ks_h == ks_d, {k: (ks_h[k], ks_d[k])
                          for k in ks_h if ks_h[k] != ks_d.get(k)}
    for gh, gd in zip(plan_h.groups, plan_d.groups):
        assert gd.reff == pytest.approx(gh.reff, rel=1e-4), gh.gid
    return lp_h, lp_d, plan_h


@pytest.mark.parametrize("cfg,name", [(CFG_MHA, "mha"), (CFG_GQA, "gqa")])
def test_plan_parity_and_token_identity(cfg, name):
    lp_h, lp_d, _ = _plan_parity(cfg, refine=True)
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    th = Engine(lp_h, cfg, ServeConfig()).generate(prompts, n_new=8)
    td = Engine(lp_d, cfg, ServeConfig()).generate(prompts, n_new=8)
    assert (th == td).all(), name


def test_plan_parity_bf16_params():
    lp_h, lp_d, plan = _plan_parity(CFG_BF16)
    for leaf in jax.tree.leaves(lp_d):
        if hasattr(leaf, "dtype") and leaf.ndim >= 2:
            assert leaf.dtype == jnp.bfloat16
    loss, _ = T.lm_loss(lp_d, CFG_BF16, _batches(CFG_BF16, n=1)[0])
    assert jnp.isfinite(loss)


def test_ragged_group_shapes_bucketed():
    """n_layers=3 + group_size=2 → every groupable type has a ragged n=1
    tail group; device bucketing must keep them in their own batch."""
    _, _, plan = _plan_parity(CFG_MHA)
    ns = {g.mtype: sorted(g2.n for g2 in plan.groups
                          if g2.mtype == g.mtype) for g in plan.groups}
    assert ns["q"] == [1, 2]          # ragged tail exists and compressed


def test_device_rsvd_plan_runs():
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    batches = _batches(cfg)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                rsvd_threshold=32)
    lp, plan = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                        streaming=False, device=True)
    assert abs(plan.summary["achieved_ratio"] - 0.3) < 0.05
    loss, _ = T.lm_loss(lp, cfg, batches[0])
    assert jnp.isfinite(loss)


def test_device_with_mesh_group_batch_sharding():
    mesh = make_host_mesh(data=1, model=1)
    params, _ = T.init_model(CFG_MHA, jax.random.PRNGKey(0))
    batches = _batches(CFG_MHA)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2)
    lp_h, plan_h = CC.build_plan_and_params(params, CFG_MHA, ccfg, batches,
                                            streaming=False)
    lp_d, plan_d = CC.build_plan_and_params(params, CFG_MHA, ccfg, batches,
                                            device=True, mesh=mesh)
    assert {g.gid: g.k for g in plan_d.groups} == \
        {g.gid: g.k for g in plan_h.groups}


@pytest.mark.parametrize("method", ["svd", "asvd", "svdllm", "fwsvd",
                                    "dranke"])
def test_device_parity_other_methods(method):
    _plan_parity(CFG_MHA, method=method, beta=0.0)


@pytest.mark.slow           # full-config sweep: every llama-mini shape
def test_plan_parity_llama_mini_full():
    cfg = get_config("llama-mini")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    batches = _batches(cfg, n=2, seq=64)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                beta=0.35)
    lp_h, plan_h = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False)
    lp_d, plan_d = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False, device=True)
    assert {g.gid: g.k for g in plan_d.groups} == \
        {g.gid: g.k for g in plan_h.groups}
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8) % cfg.vocab_size
    th = Engine(lp_h, cfg, ServeConfig()).generate(prompts, n_new=8)
    td = Engine(lp_d, cfg, ServeConfig()).generate(prompts, n_new=8)
    assert (th == td).all()


@pytest.mark.slow           # MoE sweep: routed-expert buckets on device
def test_plan_parity_moe():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(9)
    batches = [{"tokens": jax.random.randint(key, (2, 32), 0,
                                             cfg.vocab_size)}]
    ccfg = CC.CompressionConfig(method="drank", ratio=0.2, group_size=2)
    lp_h, plan_h = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False)
    lp_d, plan_d = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            streaming=False, device=True)
    assert {g.gid: g.k for g in plan_d.groups} == \
        {g.gid: g.k for g in plan_h.groups}
    xg = [g for g in plan_d.groups if g.mtype.startswith("x")]
    assert xg, "routed experts missed the device path"
    loss, _ = T.lm_loss(lp_d, cfg, batches[0])
    assert jnp.isfinite(loss)


# ---------------------------------------------------------------------------
# Streaming whitening (capture → factor → compress)
# ---------------------------------------------------------------------------
def test_streaming_whitening_factor_parity():
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg)
    oracle = CC.calibrate(lp, cfg, batches, streaming=False)
    cal = StreamingCalibrator(lp, cfg, whiten_tags=True)
    for b in batches:
        cal.ingest(b)
    col = cal.finalize()
    assert not col.gram and set(col.chol) == set(oracle.gram)
    for tag, R in col.chol.items():
        assert np.allclose(R, np.triu(R))            # upper triangular
        ref = oracle.gram[tag]
        rel = np.abs(R.T @ R - ref).max() / (np.abs(ref).max() + 1e-12)
        assert rel < 1e-4, (tag, rel)
        # absmean/count statistics still flow for whitened tags
        assert col.count[tag] == oracle.count[tag]
        assert np.allclose(col.mean_abs(tag), oracle.mean_abs(tag),
                           rtol=1e-4)


def test_streaming_whitening_flush_invariance():
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg, n=3)
    cols = []
    for fe in (1, 8):
        cal = StreamingCalibrator(lp, cfg, whiten_tags=True,
                                  flush_every=fe)
        for b in batches:
            cal.ingest(b)
        cols.append(cal.finalize())
    for tag in cols[0].chol:
        G0 = cols[0].chol[tag].T @ cols[0].chol[tag]
        G1 = cols[1].chol[tag].T @ cols[1].chol[tag]
        assert np.abs(G0 - G1).max() <= 1e-4 * (np.abs(G0).max() + 1e-12)


def test_streaming_whitening_compress_host_and_device():
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg)
    from repro.core.capture import streaming_calibrate
    col = streaming_calibrate(lp, cfg, batches, whiten_tags=True)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2)
    lp_h, plan_h = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            collector=col)
    lp_d, plan_d = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            collector=col, device=True)
    assert {g.gid: g.k for g in plan_d.groups} == \
        {g.gid: g.k for g in plan_h.groups}
    # factor-based compression tracks the gram-based oracle closely
    oracle = CC.calibrate(lp, cfg, batches, streaming=False)
    lp_o, plan_o = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            collector=oracle)
    b0 = _batches(cfg, n=1)[0]
    lo, _ = T.lm_loss(lp_o, cfg, b0)
    lh, _ = T.lm_loss(lp_h, cfg, b0)
    assert abs(float(lh) - float(lo)) < 5e-3


def test_streaming_whitening_partial_tag_subset():
    """whiten_tags can name a SUBSET of tags; groups and device buckets
    then mix factor-carrying and gram-carrying members, and compression
    must fall back to RᵀR for the factor-only tags on both paths."""
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg)
    oracle = CC.calibrate(lp, cfg, batches, streaming=False)
    some = sorted(oracle.gram)[::2]              # every other tag
    from repro.core.capture import streaming_calibrate
    col = streaming_calibrate(lp, cfg, batches, whiten_tags=some)
    assert set(col.chol) == set(some)
    assert set(col.gram) == set(oracle.gram) - set(some)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2)
    lp_h, plan_h = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            collector=col)
    lp_d, plan_d = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                            collector=col, device=True)
    assert {g.gid: g.k for g in plan_d.groups} == \
        {g.gid: g.k for g in plan_h.groups}
    loss, _ = T.lm_loss(lp_d, cfg, batches[0])
    assert jnp.isfinite(loss)


def test_factor_mode_rank_deficient_stream_stays_finite():
    """A streamed factor from fewer calibration rows than d is singular;
    the device factor path must floor its diagonal like the host
    whitener_from_factor does and keep the factors finite/close."""
    rng = np.random.default_rng(12)
    b, d1, nd2, k = 2, 32, 48, 6
    W = rng.normal(size=(b, d1, nd2))
    X = rng.normal(size=(b, d1 // 4, d1))        # rank d/4 << d
    R = np.stack([np.linalg.qr(X[i], mode="r") for i in range(b)])
    Rsq = np.zeros((b, d1, d1))
    Rsq[:, :d1 // 4, :] = R                      # upper-tri, zero diag rows
    sig, B, C = numj.decompose(W, factor=Rsq, k=k)
    B = np.asarray(B, np.float64)
    C = np.asarray(C, np.float64)
    assert np.isfinite(B).all() and np.isfinite(C).all()
    for i in range(b):
        wh = num.whitener_from_factor(Rsq[i])
        U, s, Vt = num.whitened_svd(W[i], wh)
        B0, C0 = num.truncate_factors(U, s, Vt, k, wh)
        R0, R1 = B0 @ C0, B[i] @ C[i]
        assert np.abs(R1 - R0).max() / np.abs(R0).max() < 1e-2


def test_refine_solve_factor_form_matches_gram_form():
    rng = np.random.default_rng(13)
    b, d, k, m = 2, 40, 8, 64
    B = rng.normal(size=(b, d, k))
    X = rng.normal(size=(b, 120, d))
    G = np.einsum("bni,bnj->bij", X, X)
    R = np.stack([np.linalg.qr(X[i], mode="r") for i in range(b)])
    W = rng.normal(size=(b, d, m))
    Bj = jnp.asarray(B, jnp.float32)
    Wj = jnp.asarray(W, jnp.float32)
    Cg = np.asarray(numj.refine_solve(
        Bj, jnp.asarray(G, jnp.float32), Wj), np.float64)
    Cf = np.asarray(numj.refine_solve(
        Bj, None, Wj, factor=jnp.asarray(R, jnp.float32)), np.float64)
    assert np.abs(Cf - Cg).max() / np.abs(Cg).max() < 5e-4


def test_whiten_streamed_refine_never_materializes_grams():
    """refine=True with whiten_tags=True: the refine re-capture streams
    factors too, and the whole pipeline (host or device solve) runs
    Gram-free while matching the eager-oracle refine closely."""
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg)
    from repro.core.capture import streaming_calibrate
    col = streaming_calibrate(lp, cfg, batches, whiten_tags=True)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                refine=True)
    import repro.core.compress as CCmod
    seen = {}
    orig = CCmod.calibrate

    def spy(*a, **kw):
        c = orig(*a, **kw)
        seen["gram_tags"] = len(c.gram)
        seen["chol_tags"] = len(c.chol)
        return c
    CCmod.calibrate = spy
    try:
        lp_d, _ = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                           collector=col, device=True)
    finally:
        CCmod.calibrate = orig
    assert seen == {"gram_tags": 0, "chol_tags": 22}   # refine recapture
    oracle = CC.calibrate(lp, cfg, batches, streaming=False)
    lp_o, _ = CC.build_plan_and_params(params, cfg, ccfg, batches,
                                       collector=oracle, streaming=False)
    b0 = batches[0]
    lo, _ = T.lm_loss(lp_o, cfg, b0)
    ld, _ = T.lm_loss(lp_d, cfg, b0)
    assert abs(float(ld) - float(lo)) < 5e-3


def test_device_non_finite_gram_raises_like_host():
    """Host raises on non-finite Grams (cholesky_whitener guard); the
    device path must fail as loudly, not serve NaN factors."""
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    batches = _batches(cfg, n=1)
    col = CC.calibrate(CC.to_list_params(params, cfg), cfg, batches,
                       streaming=False)
    col.gram[sorted(col.gram)[0]][0, 0] = np.nan
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2)
    with pytest.raises(np.linalg.LinAlgError, match="non-finite"):
        CC.build_plan_and_params(params, cfg, ccfg, batches,
                                 collector=col, device=True)
    with pytest.raises(np.linalg.LinAlgError, match="non-finite"):
        CC.build_plan_and_params(params, cfg, ccfg, batches,
                                 collector=col)


def test_streaming_whitening_accepts_mesh():
    """PR 5 lifted the whiten_tags+mesh rejection: per-shard QR factors
    are tree-reduced at finalize (exact on a 1-shard host mesh; the
    8-shard parity suite lives in tests/test_mesh_parity.py)."""
    cfg = CFG_MHA
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg)
    col1 = streaming_calibrate(lp, cfg, batches, whiten_tags=True)
    colm = streaming_calibrate(lp, cfg, batches, whiten_tags=True,
                               mesh=make_host_mesh())
    assert set(colm.chol) == set(col1.chol) and not colm.gram
    for tag in col1.chol:
        G1 = col1.chol[tag].T @ col1.chol[tag]
        Gm = colm.chol[tag].T @ colm.chol[tag]
        rel = np.abs(G1 - Gm).max() / (np.abs(G1).max() + 1e-12)
        assert rel < 1e-6, (tag, rel)
