"""Shared fixtures. NOTE: no XLA_FLAGS / device-count overrides here —
smoke tests and benches must see the real single CPU device. Only
``repro/launch/dryrun.py`` (run as its own process) forces 512 host devices.
"""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_batch(cfg, key, batch=2, seq=16, enc_len=12):
    """Standard smoke batch for any assigned architecture."""
    kt, ke, kl, kenc = jax.random.split(key, 4)
    b = {}
    if cfg.is_encoder_decoder:
        # frontend stub (audio) feeds the ENCODER; the decoder sees tokens
        b["enc_embeds"] = 0.02 * jax.random.normal(
            kenc, (batch, enc_len, cfg.d_model), dtype=jnp.float32)
        b["tokens"] = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    elif cfg.frontend:
        # decoder-only multimodal backbone (vlm): precomputed patch embeds
        b["embeds"] = 0.02 * jax.random.normal(
            ke, (batch, seq, cfg.d_model), dtype=jnp.float32)
        b["labels"] = jax.random.randint(kl, (batch, seq), 0, cfg.vocab_size)
    else:
        b["tokens"] = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    return b
