"""PR-5 tentpole acceptance: mesh-parity suite on a FORCED 8-device host
mesh (``--xla_force_host_platform_device_count=8``).

jax locks the local device count at first backend init, and the rest of
the suite needs the real single CPU device (tests/conftest.py), so the
mesh checks run in a subprocess: ``tests/mesh_parity_main.py`` executes
every assertion (tree-reduced whitening factor ≤1e-6 vs the 1-shard
chain, sharded-vs-replicated accumulator flush equality + sharding-spec
assertions, flush-cadence invariance, identical ranks / token-identical
serve from a mesh-captured plan) and prints ``MESH_PARITY_OK``.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


@pytest.mark.slow       # multi-process smoke (repo marker convention)
def test_mesh_parity_suite_on_8_device_host_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "mesh_parity_main.py")],
        capture_output=True, text=True, env=env, timeout=1200)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, (proc.returncode, proc.stderr[-2000:])
    assert "MESH_PARITY_OK" in proc.stdout
