"""PR-2 tentpole coverage: device-side streaming calibration capture and
the compressed-checkpoint serving round trip.

Parity bars (ISSUE acceptance): the jit/device capture Gram must match the
eager fp64 host oracle within 1e-4 relative on EVERY tag, and an engine
booted from a saved compressed checkpoint must decode token-identically to
one compressed in-process.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import compress as CC
from repro.core.capture import (Collector, StreamingCalibrator,
                                discover_capture_dims, streaming_calibrate,
                                tag_linears, to_list_params)
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serve.engine import ContinuousBatcher, Engine, Request, \
    ServeConfig

RTOL = 1e-4

CFG = get_config("llama-mini").replace(n_layers=2, d_model=64, n_heads=4,
                                       n_kv_heads=4, head_dim=16, d_ff=128,
                                       vocab_size=256, rank_multiple=4)


def _batches(cfg, n=2, batch=2, seq=32, seed=7):
    key = jax.random.PRNGKey(seed)
    return [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                          (batch, seq), 0, cfg.vocab_size)}
            for i in range(n)]


def _eager(lp, cfg, batches) -> Collector:
    return CC.calibrate(lp, cfg, batches, streaming=False)


def _assert_parity(got: Collector, oracle: Collector, rtol=RTOL):
    assert set(got.gram) == set(oracle.gram), \
        set(got.gram) ^ set(oracle.gram)
    for tag in oracle.gram:
        ref = oracle.gram[tag]
        rel = np.abs(got.gram[tag] - ref).max() / (np.abs(ref).max() + 1e-12)
        assert rel < rtol, (tag, rel)
        aref = oracle.absmean[tag]
        arel = np.abs(got.absmean[tag] - aref).max() / (
            np.abs(aref).max() + 1e-12)
        assert arel < rtol, (tag, arel)
        assert got.count[tag] == oracle.count[tag], tag


# ---------------------------------------------------------------------------
# gram_blocked vs fp64 numpy oracle (padded / ragged N, interpret mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("N,D", [(7, 12), (100, 48), (513, 96), (64, 64)])
def test_gram_kernel_vs_fp64_numpy_oracle(N, D):
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D), dtype=jnp.float32)
    g = np.asarray(ops.gram(x), dtype=np.float64)     # pad-and-mask wrapper
    xn = np.asarray(x, dtype=np.float64)
    ref = xn.T @ xn
    rel = np.abs(g - ref).max() / (np.abs(ref).max() + 1e-12)
    assert rel < RTOL, rel


def test_gram_kernel_zero_pad_rows_are_exact():
    """Zero-padding the token axis must not perturb G at all."""
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 24), jnp.float32)
    xp = jnp.concatenate([x, jnp.zeros((22, 24), jnp.float32)], axis=0)
    assert jnp.allclose(ops.gram(x), ops.gram(xp), atol=0.0)


# ---------------------------------------------------------------------------
# streaming capture vs eager fp64 oracle
# ---------------------------------------------------------------------------
def test_streaming_matches_eager_oracle_every_tag():
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    batches = _batches(CFG, n=3)
    oracle = _eager(lp, CFG, batches)
    col = streaming_calibrate(lp, CFG, batches)
    _assert_parity(col, oracle)


def test_streaming_flush_boundary_invariance():
    """fp64 host sums must not depend on the fp32 flush cadence."""
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    batches = _batches(CFG, n=3)
    col1 = streaming_calibrate(lp, CFG, batches, flush_every=1)
    col8 = streaming_calibrate(lp, CFG, batches, flush_every=8)
    for tag in col1.gram:
        rel = np.abs(col1.gram[tag] - col8.gram[tag]).max() / (
            np.abs(col8.gram[tag]).max() + 1e-12)
        assert rel < 1e-6, (tag, rel)


def test_streaming_pallas_gram_kernel_path():
    """Interpret-mode evidence that the TPU gram kernel feeds the stream."""
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    batches = _batches(CFG, n=1)
    oracle = _eager(lp, CFG, batches)
    col = streaming_calibrate(lp, CFG, batches, use_kernel=True)
    _assert_parity(col, oracle)


def test_streaming_mesh_psum_path():
    """Shard-aware accumulation: per-shard partials psum'd in shard_map."""
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    batches = _batches(CFG, n=2)
    oracle = _eager(lp, CFG, batches)
    mesh = make_host_mesh(data=1, model=1)
    col = streaming_calibrate(lp, CFG, batches, mesh=mesh)
    _assert_parity(col, oracle)


@pytest.mark.slow           # MoE capture sweep (per-expert dispatch Grams)
def test_streaming_moe_expert_capture():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = to_list_params(params, cfg)
    batches = _batches(cfg, n=1, seq=16)
    oracle = _eager(lp, cfg, batches)
    col = streaming_calibrate(lp, cfg, batches)
    assert any("/expert" in t for t in col.gram)
    _assert_parity(col, oracle)


def test_discovery_and_ragged_batch_shapes():
    """Tag/dim discovery is abstract (no FLOPs) and the calibrator accepts
    mixed batch shapes (one retrace per shape, stats still exact)."""
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    tagged = tag_linears(lp)
    dims = discover_capture_dims(tagged, CFG, _batches(CFG, n=1)[0])
    assert all(isinstance(d, int) for d in dims.values()) and dims
    mixed = _batches(CFG, n=1, batch=2, seq=32) + \
        _batches(CFG, n=1, batch=1, seq=16, seed=11)
    oracle = _eager(lp, CFG, mixed)
    cal = StreamingCalibrator(lp, CFG, flush_every=100)
    for b in mixed:
        cal.ingest(b)
    _assert_parity(cal.finalize(), oracle)


def test_eager_collector_refuses_tracers():
    col = Collector()
    with pytest.raises(RuntimeError, match="streaming"):
        with col:
            jax.jit(lambda x: col.add("t", x) or x)(jnp.ones((2, 4)))


# ---------------------------------------------------------------------------
# compress -> save -> restore -> serve round trip
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def compressed_mini():
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    batches = _batches(CFG, n=1)
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                beta=0.3)
    comp, plan = CC.build_plan_and_params(params, CFG, ccfg, batches)
    return comp, plan


def test_save_restore_serve_token_identical(compressed_mini, tmp_path):
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    loaded, plan2 = CC.load_plan(str(tmp_path), cfg=CFG)
    assert plan2.to_json() == plan.to_json()
    # deduped shared bases survive the round trip byte- and identity-wise
    assert CC.compressed_param_count(loaded) == \
        CC.compressed_param_count(comp)
    for a, b in zip(jax.tree.leaves(comp), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % CFG.vocab_size
    eng_mem = Engine(comp, CFG, ServeConfig())
    eng_ckpt = Engine.from_compressed(str(tmp_path), CFG, ServeConfig())
    assert eng_ckpt.plan is not None
    assert (eng_mem.generate(prompts, n_new=8)
            == eng_ckpt.generate(prompts, n_new=8)).all()


def test_batcher_boots_from_compressed(compressed_mini, tmp_path):
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    cb = ContinuousBatcher.from_compressed(
        str(tmp_path), CFG, ServeConfig(batch=2, max_len=48))
    rng = np.random.default_rng(0)
    for i in range(3):
        cb.submit(Request(rid=i, tokens=rng.integers(
            0, CFG.vocab_size, size=(5 + i,), dtype=np.int32), n_new=4))
    done = cb.run_until_drained()
    assert len(done) == 3
    eng = Engine(comp, CFG, ServeConfig())
    for r in done:
        ref = eng.generate(r.tokens[None, :], n_new=4)[0]
        assert (np.asarray(r.out) == ref).all()


def test_load_plan_rejects_wrong_config(compressed_mini, tmp_path):
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    with pytest.raises(ValueError, match="built for"):
        CC.load_plan(str(tmp_path), cfg=CFG.replace(n_layers=4))


def test_save_plan_artifact_dedupes_shared_bases(compressed_mini, tmp_path):
    """Group members share their basis B by object identity; the artifact
    must store each shared basis ONCE."""
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    unique = len({id(a) for a in jax.tree.leaves(comp)})
    arrays = np.load(str(tmp_path / "compressed" / "arrays.npz"))
    assert len(arrays.files) == unique
    total = len(jax.tree.leaves(comp))
    assert unique < total     # grouping actually shared something


def test_artifact_verify_passes_on_clean_artifact(compressed_mini, tmp_path):
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    loaded, _ = CC.load_plan(str(tmp_path), cfg=CFG, verify=True)
    for a, b in zip(jax.tree.leaves(comp), jax.tree.leaves(loaded)):
        assert jnp.array_equal(a, b)
    eng = Engine.from_compressed(str(tmp_path), CFG, ServeConfig(),
                                 verify=True)
    assert eng.plan is not None


def test_artifact_verify_catches_corruption(compressed_mini, tmp_path):
    """A single flipped array in arrays.npz must fail --verify at boot."""
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    npz = tmp_path / "compressed" / "arrays.npz"
    arrays = dict(np.load(str(npz)))
    victim = sorted(arrays)[0]
    arrays[victim] = arrays[victim] + np.float32(1e-3)
    np.savez(str(npz), **arrays)
    with pytest.raises(ValueError, match="integrity"):
        CC.load_plan(str(tmp_path), cfg=CFG, verify=True)
    # unverified load still works (prod escape hatch for legacy artifacts)
    loaded, _ = CC.load_plan(str(tmp_path), cfg=CFG)
    assert loaded is not None


def test_artifact_verify_rejects_unhashed_manifest(compressed_mini,
                                                   tmp_path):
    import json as _json
    comp, plan = compressed_mini
    CC.save_plan(str(tmp_path), comp, plan, CFG)
    mpath = tmp_path / "compressed" / "manifest.json"
    manifest = _json.loads(mpath.read_text())
    del manifest["hashes"]
    mpath.write_text(_json.dumps(manifest))
    with pytest.raises(ValueError, match="no content hashes"):
        CC.load_plan(str(tmp_path), cfg=CFG, verify=True)


def test_pytree_store_roundtrip_bf16_and_lists(tmp_path):
    from repro.ckpt import store
    tree = {"a": [jnp.ones((2, 3), jnp.bfloat16),
                  {"b": jnp.arange(4, dtype=jnp.int32)}],
            "c": (jnp.zeros((1,), jnp.float32),)}
    store.save_pytree(str(tmp_path), tree, meta={"k": 1})
    back, meta = store.load_pytree(str(tmp_path))
    assert meta == {"k": 1}
    assert isinstance(back["a"], list) and isinstance(back["c"], tuple)
    assert back["a"][0].dtype == jnp.bfloat16
    assert jnp.array_equal(back["a"][1]["b"], tree["a"][1]["b"])
