"""Decode fast path: ragged Pallas decode-attention kernel parity, the
decode-shaped low-rank GEMV, bucketed batched admission in the
ContinuousBatcher (bounded retraces, identical outputs), and the
measure_decode_throughput warmup fixes."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import transformer as T
from repro.models.params import set_use_pallas
from repro.serve.engine import (ContinuousBatcher, Engine, Request,
                                ServeConfig)


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# decode-attention kernel parity (interpret mode) vs the jnp oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,L,window,cap", [
    (2, 4, 2, 16, 32, 0, 0.0),     # GQA, full cache
    (3, 4, 4, 32, 24, 0, 0.0),     # MHA, ragged cache length (pads)
    (2, 4, 1, 16, 64, 0, 0.0),     # MQA
    (2, 4, 2, 16, 8, 8, 0.0),      # GQA, ring buffer
    (2, 6, 2, 16, 8, 8, 30.0),     # ring + logit softcap
    (1, 2, 2, 64, 512, 0, 0.0),    # long cache, short lengths (block skip)
])
def test_decode_attention_parity(B, H, KV, hd, L, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rnd(ks[0], (B, H, hd), dtype)
    k = rnd(ks[1], (B, L, KV, hd), dtype)
    v = rnd(ks[2], (B, L, KV, hd), dtype)
    span = window if window else L
    # ragged lengths: cover 1, mid, and the full span across the batch
    lengths = jnp.asarray(
        [1 + (i * (span - 1)) // max(B - 1, 1) for i in range(B)],
        dtype=jnp.int32) if B > 1 else jnp.asarray([span], dtype=jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, window=window, softcap=cap)
    r = ref.decode_attention(q, k, v, lengths, window=window, softcap=cap)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - r.astype(jnp.float32))))
    assert err <= 1e-2, err


def test_decode_attention_zero_length_rows_exact_zero():
    """Dead slots (length 0 — freshly purged or never used) must emit
    EXACT zeros and never read the cache: the old kernel clamped the
    block count to >= 1 and read row 0's keys for a dead slot."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, H, KV, hd, L = 3, 4, 2, 16, 32
    q = rnd(ks[0], (B, H, hd))
    k = rnd(ks[1], (B, L, KV, hd))
    v = rnd(ks[2], (B, L, KV, hd))
    lengths = jnp.asarray([0, 5, 0], dtype=jnp.int32)
    o = np.asarray(ops.decode_attention(q, k, v, lengths))
    r = np.asarray(ref.decode_attention(q, k, v, lengths))
    assert (o[0] == 0).all() and (o[2] == 0).all()
    assert (r[0] == 0).all() and (r[2] == 0).all()
    assert float(np.max(np.abs(o - r))) < 1e-4


def test_decode_attention_paged_parity():
    """Block-table paged kernel vs the contiguous oracle on a shuffled
    arena: gathering each slot's blocks back into a contiguous cache and
    running the reference must match; dead rows (all-null table) zero."""
    bk, B, NB, H, KV, hd = 16, 3, 4, 4, 2, 16
    P = B * NB + 1
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = rnd(ks[0], (B, H, hd))
    ka = rnd(ks[1], (P, bk, KV, hd)).at[0].set(0.0)
    va = rnd(ks[2], (P, bk, KV, hd)).at[0].set(0.0)
    perm = np.random.default_rng(7).permutation(np.arange(1, P))
    lengths = np.asarray([37, 0, NB * bk], dtype=np.int32)
    table = np.zeros((B, NB), dtype=np.int32)
    j = 0
    for b in range(B):
        nblk = -(-int(lengths[b]) // bk)
        table[b, :nblk] = perm[j:j + nblk]
        j += nblk
    o = ops.decode_attention_paged(q, ka, va, jnp.asarray(lengths),
                                   jnp.asarray(table))
    kc = ka[table.reshape(-1)].reshape(B, NB * bk, KV, hd)
    vc = va[table.reshape(-1)].reshape(B, NB * bk, KV, hd)
    r = ref.decode_attention(q, kc, vc, jnp.asarray(lengths))
    assert (np.asarray(o[1]) == 0).all()
    assert float(jnp.max(jnp.abs(o - r))) < 1e-2


def test_decode_attention_ring_wraparound():
    """Ring lengths far past the window: every slot live, ages wrap."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, KV, hd, w = 2, 4, 2, 16, 8
    q = rnd(ks[0], (B, H, hd))
    k = rnd(ks[1], (B, w, KV, hd))
    v = rnd(ks[2], (B, w, KV, hd))
    lengths = jnp.asarray([3 * w + 5, 7 * w + 1], dtype=jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, window=w)
    r = ref.decode_attention(q, k, v, lengths, window=w)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-4


def test_attend_decode_pallas_matches_jnp():
    """End-to-end decode step: kernel path == jnp path, full + ring archs."""
    for arch in ("llama-mini", "gemma3-12b"):
        cfg = get_config(arch).reduced()
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        _, cache = T.prefill(params, cfg, {"tokens": toks}, max_len=20)
        nxt = toks[:, -1:]
        l0, _ = T.decode_step(params, cfg, cache, nxt)
        set_use_pallas(True)
        try:
            l1, _ = T.decode_step(params, cfg, cache, nxt)
        finally:
            set_use_pallas(False)
        err = float(jnp.max(jnp.abs(l0 - l1)))
        assert err < 2e-3, (arch, err)


# ---------------------------------------------------------------------------
# decode-shaped low-rank GEMV
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,R,N", [
    (1, 256, 16, 256),      # single decode token
    (8, 200, 24, 300),      # ragged K/N (128-aligned padding)
    (33, 512, 8, 1024),     # odd batch
    (64, 1024, 128, 640),   # dispatch boundary (largest GEMV shape)
    (65, 256, 16, 256),     # just past the boundary -> tiled kernel
])
def test_lowrank_gemv_parity(M, K, R, N):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = rnd(ks[0], (M, K))
    B = rnd(ks[1], (K, R)) * 0.1
    C = rnd(ks[2], (R, N)) * 0.1
    y = ops.lowrank_matmul(x, B, C)
    yr = ref.lowrank_matmul(x, B, C)
    err = float(jnp.max(jnp.abs(y - yr)))
    scale = float(jnp.max(jnp.abs(yr))) + 1e-6
    assert err / scale < 2e-5, (err, scale)


def test_lowrank_gemv_grads_still_flow():
    """The shape dispatch lives inside custom_vjp fwd; grads stay exact."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = rnd(ks[0], (4, 64))
    B = rnd(ks[1], (64, 8)) * 0.2
    C = rnd(ks[2], (8, 32)) * 0.2
    g1 = jax.grad(lambda *a: jnp.sum(ops.lowrank_matmul(*a) ** 2),
                  argnums=(0, 1, 2))(x, B, C)
    g2 = jax.grad(lambda x, B, C: jnp.sum(((x @ B) @ C) ** 2),
                  argnums=(0, 1, 2))(x, B, C)
    for a, b in zip(g1, g2):
        assert jnp.allclose(a, b, atol=1e-3)


# ---------------------------------------------------------------------------
# ragged non-causal flash stays on the kernel path
# ---------------------------------------------------------------------------
def test_flash_ragged_bidirectional_kernel_path(monkeypatch):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rnd(ks[0], (1, 60, 2, 32))
    k = rnd(ks[1], (1, 60, 2, 32))
    v = rnd(ks[2], (1, 60, 2, 32))

    def boom(*a, **kw):    # the old silent fallback must be gone
        raise AssertionError("ragged bidirectional fell back to reference")
    monkeypatch.setattr(ref, "flash_attention", boom)
    o = ops.flash_attention(q, k, v, False, 0, 0.0)
    monkeypatch.undo()
    r = ref.flash_attention(q, k, v, causal=False)
    assert float(jnp.max(jnp.abs(o - r))) < 1e-4


# ---------------------------------------------------------------------------
# ContinuousBatcher: bucketed batched admission
# ---------------------------------------------------------------------------
CFG = get_config("llama-mini").replace(n_layers=2, d_model=64, n_heads=4,
                                       n_kv_heads=2, head_dim=16, d_ff=128,
                                       vocab_size=256)


@pytest.fixture(scope="module")
def mini_params():
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return params


def _mixed_requests(n, seed=0, max_prompt=21):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(
                        0, CFG.vocab_size,
                        size=(int(rng.integers(1, max_prompt)),),
                        dtype=np.int32),
                    n_new=4) for i in range(n)]


def test_batcher_bucketed_matches_sequential(mini_params):
    scfg = ServeConfig(batch=3, max_len=64, temperature=0.0)
    cb = ContinuousBatcher(mini_params, CFG, scfg)
    assert cb.bucketed
    reqs = _mixed_requests(7, seed=1)
    for r in reqs:
        cb.submit(r)
    done = cb.run_until_drained()
    assert len(done) == 7
    eng = Engine(mini_params, CFG, ServeConfig(temperature=0.0))
    for r in done:
        want = eng.generate(r.tokens[None, :], n_new=r.n_new)[0]
        assert (np.asarray(r.out) == want).all(), (r.rid, r.out, want)


def test_batcher_retrace_bound(mini_params):
    """Arbitrary mixed-length workloads compile _prefill1 at most
    ceil(log2(max_len)) times and decode exactly once."""
    scfg = ServeConfig(batch=4, max_len=64, temperature=0.0)
    cb = ContinuousBatcher(mini_params, CFG, scfg)
    # staggered submits across many drain cycles: lengths 1..40 hit every
    # bucket repeatedly, admission batch sizes vary
    lens = list(range(1, 41))
    rng = np.random.default_rng(2)
    rng.shuffle(lens)
    for i, L in enumerate(lens):
        cb.submit(Request(
            rid=i, tokens=rng.integers(0, CFG.vocab_size, size=(L,),
                                       dtype=np.int32), n_new=2))
        if i % 5 == 4:
            cb.step()
    done = cb.run_until_drained()
    assert len(done) == len(lens)
    bound = math.ceil(math.log2(scfg.max_len))
    assert cb.stats["prefill_retraces"] <= bound, cb.stats
    assert cb.stats["decode_retraces"] == 1, cb.stats
    assert cb.stats["admitted"] == len(lens)


def test_batcher_exact_path_for_stateful_archs():
    cfg = get_config("xlstm-350m").reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch=2, max_len=32, temperature=0.0)
    cb = ContinuousBatcher(params, cfg, scfg)
    assert not cb.bucketed     # recurrent state: no right-padding
    rng = np.random.default_rng(3)
    for i in range(3):
        cb.submit(Request(rid=i,
                          tokens=rng.integers(0, cfg.vocab_size, size=(4 + i,),
                                              dtype=np.int32),
                          n_new=3))
    done = cb.run_until_drained()
    assert len(done) == 3
    eng = Engine(params, cfg, ServeConfig(temperature=0.0))
    for r in done:
        want = eng.generate(r.tokens[None, :], n_new=3)[0]
        assert (np.asarray(r.out) == want).all(), (r.rid, r.out, want)


def test_engine_retrace_bound(mini_params):
    """``Engine.generate`` / ``measure_decode_throughput`` used to build
    a fresh ``jax.jit`` closure per call, so EVERY call retraced the full
    prefill and decode. The memoized executables trace once per cache
    capacity and are shared between the two entry points."""
    eng = Engine(mini_params, CFG, ServeConfig(temperature=0.0))
    p = np.random.default_rng(0).integers(0, CFG.vocab_size, size=(2, 8),
                                          dtype=np.int32)
    first = eng.generate(p, n_new=4)
    for _ in range(3):
        assert (eng.generate(p, n_new=4) == first).all()
    assert eng.stats["prefill_retraces"] == 1, eng.stats
    assert eng.stats["decode_retraces"] == 1, eng.stats
    # the throughput meter at the same capacity reuses both executables
    for _ in range(2):
        eng.measure_decode_throughput(batch=2, prompt_len=8, n_new=4,
                                      warmup=0)
    assert eng.stats["prefill_retraces"] == 1, eng.stats
    assert eng.stats["decode_retraces"] == 1, eng.stats
    # a new cache capacity costs one more trace of each — not one per call
    for _ in range(2):
        eng.generate(p, n_new=6)
    assert eng.stats["prefill_retraces"] == 2, eng.stats
    assert eng.stats["decode_retraces"] == 2, eng.stats


# ---------------------------------------------------------------------------
# throughput meter warmup fixes
# ---------------------------------------------------------------------------
def test_throughput_meter_zero_warmup(mini_params):
    eng = Engine(mini_params, CFG, ServeConfig())
    m = eng.measure_decode_throughput(batch=2, prompt_len=8, n_new=3,
                                      warmup=0)
    assert m["tokens_per_s"] > 0


def test_throughput_meter_warmup_advances(mini_params):
    eng = Engine(mini_params, CFG, ServeConfig())
    m = eng.measure_decode_throughput(batch=2, prompt_len=8, n_new=3,
                                      warmup=2)
    assert m["tokens_per_s"] > 0
