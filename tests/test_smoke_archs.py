"""Per-architecture smoke tests: reduced same-family config, one forward +
one train (grad) step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only by the dry-run (ShapeDtypeStruct — no
allocation); these reduced configs preserve the family structure (GQA
ratios, MoE routing, local:global pattern, enc-dec, hybrid heads).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T

from conftest import make_batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch, rng):
    cfg = get_config(arch).reduced()
    params, specs = T.init_model(cfg, rng)
    # every param leaf has a matching spec leaf
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))

    batch = make_batch(cfg, jax.random.fold_in(rng, 1))
    logits, aux = T.forward(params, cfg, batch)
    B, S = (batch.get("tokens") if "tokens" in batch
            else batch["embeds"]).shape[:2]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    def loss_fn(p):
        return T.lm_loss(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_scan_unroll_equivalence(arch, rng):
    """scan_layers=True and False are the same function."""
    cfg = get_config(arch).reduced()
    params, _ = T.init_model(cfg, rng)
    batch = make_batch(cfg, jax.random.fold_in(rng, 2), batch=1, seq=8)
    l1, _ = T.forward(params, cfg, batch)
    l2, _ = T.forward(params, cfg.replace(scan_layers=False), batch)
    assert jnp.allclose(l1, l2, atol=2e-5), float(jnp.max(jnp.abs(l1 - l2)))


def test_param_counts_full_configs():
    """Full configs instantiate their *shape* structure correctly (abstract
    init only — no memory allocated)."""
    expected_order = {
        # rough parameter counts (embedding included), 20% slack
        "smollm-360m": 360e6, "xlstm-350m": 350e6,
        "qwen3-4b": 4e9, "mistral-nemo-12b": 12e9,
    }
    for arch, approx in expected_order.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k: T.init_model(cfg, k)[0], jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree.leaves(shapes))
        assert 0.5 * approx < n < 2.0 * approx, (arch, n, approx)
