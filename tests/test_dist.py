"""Distribution: logical sharding rules, shape-aware fallback, and a real
(8 fake device) sharded train-step execution in a subprocess (device count
must be set before jax init, so it cannot run in this process)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.dist import sharding as SH
from repro.dist import ft

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_logical_spec_resolution():
    mesh = FakeMesh({"data": 16, "model": 16})
    assert SH.logical_spec(("batch", None, "mlp"), mesh) == \
        P("data", None, "model")
    # pod folds away on the single-pod mesh
    mesh3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert SH.logical_spec(("batch",), mesh3) == P(("pod", "data"))


def test_duplicate_axis_falls_back():
    mesh = FakeMesh({"data": 16, "model": 16})
    # "batch" takes data; "fsdp" wants (pod,data) -> data already used
    spec = SH.logical_spec(("batch", "fsdp"), mesh)
    assert spec == P("data", None)


def test_shape_aware_spec_divisibility():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 8 kv heads cannot shard 16 ways -> replicated
    spec = SH.shape_aware_spec((32, 1024, 8, 128),
                               ("batch", "kv_seq", "kv_heads", None), mesh)
    assert spec == P("data", "model", None, None)
    # partial tuple: batch 8 divides data 16? no -> drops
    spec = SH.shape_aware_spec((8, 64), ("batch", None),
                               FakeMesh({"pod": 2, "data": 16, "model": 1}))
    assert spec == P(("pod",), None) or spec == P(None, None)


def test_ft_heartbeat_and_stall_detection(tmp_path):
    hb = ft.Heartbeat(str(tmp_path / "worker_0"), worker_id=0)
    hb.beat(42)
    assert hb.read()["step"] == 42
    stalled = ft.detect_stalled(str(tmp_path), deadline_s=1e-9)
    assert "worker_0" in stalled
    assert ft.detect_stalled(str(tmp_path), deadline_s=3600) == []


def test_ft_shard_rows_cover():
    rows = np.concatenate([ft.shard_rows(64, 4, i) for i in range(4)])
    assert (np.sort(rows) == np.arange(64)).all()
    assert (ft.speculative_shard(64, 4, 2, 0) == ft.shard_rows(64, 4, 2)).all()


@pytest.mark.slow
def test_sharded_train_step_subprocess():
    """Real sharded execution: 8 fake devices, (4, 2) mesh, three train
    steps; asserts sharded losses match the single-device run."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.data.synthetic import DataConfig, ShardedLoader
        from repro.dist import sharding as SH
        from repro.launch.mesh import make_host_mesh
        from repro.optim.adamw import OptimizerConfig
        from repro.train import step as TS

        cfg = get_config("llama-mini").replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8)
        loader = ShardedLoader(dcfg)
        tcfg = TS.TrainConfig(optimizer=OptimizerConfig(
            lr=1e-3, warmup_steps=2, total_steps=10))

        def run(mesh):
            state, specs = TS.init_train_state(cfg, jax.random.PRNGKey(0))
            losses = []
            if mesh is None:
                fn = jax.jit(TS.make_train_step(cfg, tcfg))
                for s in range(3):
                    b = {k: jnp.asarray(v)
                         for k, v in loader.batch(s).items()}
                    state, m = fn(state, b)
                    losses.append(float(m["loss"]))
                return losses
            with mesh, SH.use_rules({}, mesh=mesh):
                p_sh = SH.shardings_for_tree(state.params, specs, mesh)
                opt_sh = TS.AdamWState(
                    step=jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec()),
                    mu=p_sh, nu=p_sh)
                st_sh = TS.TrainState(params=p_sh, opt=opt_sh)
                state = jax.device_put(state, st_sh)
                fn = jax.jit(TS.make_train_step(cfg, tcfg),
                             in_shardings=(st_sh, None),
                             out_shardings=(st_sh, None))
                for s in range(3):
                    b = {k: jnp.asarray(v)
                         for k, v in loader.batch(s).items()}
                    state, m = fn(state, b)
                    losses.append(float(m["loss"]))
                return losses

        single = run(None)
        sharded = run(make_host_mesh(4, 2))
        print(json.dumps({"single": single, "sharded": sharded}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for a, b in zip(res["single"], res["sharded"]):
        assert abs(a - b) < 5e-3, res
