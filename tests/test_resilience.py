"""Chaos suite (ISSUE 6): deterministic fault injection against the
continuous batcher's resilience layer.

The load-bearing oracle is a FAULT-FREE run of the same workload: under
every injected fault, each request that is not deliberately shed must
complete with EXACTLY the tokens of the clean run (greedy decode is
deterministic; quarantine replays a victim from its prompt, so a
transient fault is invisible in the output stream). Shedding decisions
must be seed-deterministic: two identical runs shed identical rid sets.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compress as CC
from repro.dist import faultinject as FI
from repro.models import transformer as T
from repro.serve import admission as adm
from repro.serve.engine import (ContinuousBatcher, DrainResult, Request,
                                ServeConfig)

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
SCFG = ServeConfig(batch=4, max_len=64)


@pytest.fixture(scope="module")
def params():
    p, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return p


@pytest.fixture(scope="module")
def comp(params):
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    cfg = CFG.replace(rank_multiple=1)
    c, _ = CC.build_plan_and_params(
        params, cfg, CC.CompressionConfig(ratio=0.4), calib)
    return c


def make_requests(n=6, n_new=5, seed=0, deadline_s=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, n_new=n_new, deadline_s=deadline_s,
                    tokens=rng.integers(0, CFG.vocab_size, size=(7,),
                                        dtype=np.int32))
            for i in range(n)]


def drain(params, reqs, **kw):
    watchdog = kw.pop("watchdog_s", None)
    max_steps = kw.pop("max_steps", 100000)
    cb = ContinuousBatcher(params, CFG, SCFG, **kw)
    for r in reqs:
        cb.submit(r)
    res = cb.run_until_drained(max_steps=max_steps, watchdog_s=watchdog)
    return cb, res


@pytest.fixture(scope="module")
def oracle(params):
    """Token oracle: the fault-free run every chaos run must match."""
    _, res = drain(params, make_requests())
    assert res.status == "drained" and len(res) == 6
    return {r.rid: list(r.out) for r in res}


def assert_identical(res, oracle, rids=None):
    got = {r.rid: list(r.out) for r in res}
    want = {k: v for k, v in oracle.items()
            if rids is None or k in rids}
    assert got == want


# ---------------------------------------------------------------------------
# NaN-logit quarantine
# ---------------------------------------------------------------------------
def test_nan_decode_single_row_token_identity(params, oracle):
    plan = FI.FaultPlan(nan_decode_step=2, nan_rows=(1,))
    cb, res = drain(params, make_requests(), faults=plan)
    m = cb.metrics()
    assert res.status == "drained"
    assert m["poison_events"] == 1 and m["poison_retries"] == 1
    assert m["poison_failures"] == 0 and m["slot_purges"] == 1
    assert any(f.startswith("nan_decode@2") for f in plan.fired)
    assert_identical(res, oracle)    # victim replayed bit-identically


def test_nan_decode_seeded_row_is_deterministic(params, oracle):
    """With no row pinned, the poisoned slot is chosen by (seed, step) —
    two runs fire on the same row and produce identical metrics."""
    fired = []
    for _ in range(2):
        plan = FI.FaultPlan(seed=7, nan_decode_step=3)
        cb, res = drain(params, make_requests(), faults=plan)
        assert res.status == "drained"
        assert_identical(res, oracle)
        fired.append(plan.fired)
    assert fired[0] == fired[1]


def test_nan_decode_all_rows_bisects_and_recovers(params, oracle):
    """Every live row non-finite at once: attribution is ambiguous, the
    quarantine bisects (isolated replay probes), finds no persistent
    offender, and replays everyone — still token-identical."""
    plan = FI.FaultPlan(nan_decode_step=1, nan_rows="all")
    cb, res = drain(params, make_requests(), faults=plan)
    m = cb.metrics()
    assert res.status == "drained"
    assert m["poison_events"] == 1 and m.get("poison_probes", 0) >= 1
    assert m["poison_failures"] == 0
    assert_identical(res, oracle)


def test_nan_prefill_admission_token_identity(params, oracle):
    plan = FI.FaultPlan(nan_prefill_admission=0, nan_rows=(0,))
    cb, res = drain(params, make_requests(), faults=plan)
    assert res.status == "drained"
    assert any(f.startswith("nan_prefill@0") for f in plan.fired)
    assert_identical(res, oracle)


def test_persistent_poison_fails_typed_others_unharmed(params, oracle):
    """A request whose content reliably breaks the model must exhaust its
    retry budget and fail with a typed status — never stall the engine,
    never corrupt its batch-mates' outputs."""
    plan = FI.FaultPlan(poison_rids=(2,))
    cb, res = drain(params, make_requests(), faults=plan,
                    admission=adm.AdmissionConfig(max_retries=1))
    m = cb.metrics()
    assert res.status == "drained"
    assert [r.rid for r in res.failed] == [2]
    assert res.failed[0].status == adm.FAILED_POISON
    assert "non-finite logits" in res.failed[0].error
    assert m["poison_failures"] == 1
    assert m["poison_retries"] == 2          # budget 1 => 2 attempts
    assert_identical(res, oracle, rids={0, 1, 3, 4, 5})


# ---------------------------------------------------------------------------
# Deadlines, backpressure, flood
# ---------------------------------------------------------------------------
def test_deadline_shedding_is_deterministic(params, oracle):
    """Overdue requests (deadline already passed) shed identically across
    runs; survivors complete token-identically."""
    outcomes = []
    for _ in range(2):
        reqs = make_requests()
        for r in reqs:
            if r.rid % 2:
                r.deadline_s = -1.0          # overdue the moment it queues
        cb, res = drain(params, reqs)
        assert res.status == "drained"
        assert_identical(res, oracle, rids={0, 2, 4})
        shed = sorted(r.rid for r in res.shed)
        assert all(r.status == adm.SHED_DEADLINE for r in res.shed)
        outcomes.append((shed, sorted(r.rid for r in res)))
    assert outcomes[0] == outcomes[1] == ([1, 3, 5], [0, 2, 4])


def test_queue_flood_backpressure(params):
    """A flood past --max-queue: exactly max_queue requests are accepted,
    the rest are rejected AT SUBMIT with a typed status, and every
    accepted request completes."""
    flood = FI.flood_requests(20, CFG.vocab_size, seed=3)
    cb = ContinuousBatcher(params, CFG, SCFG,
                           admission=adm.AdmissionConfig(max_queue=5))
    verdicts = [cb.submit(r) for r in flood]
    assert sum(verdicts) == 5 and verdicts[:5] == [True] * 5
    assert len(cb.admission.rejected) == 15
    assert all(r.status == adm.SHED_QUEUE_FULL
               for r in cb.admission.rejected)
    res = cb.run_until_drained()
    assert res.status == "drained" and len(res) == 5
    m = cb.metrics()
    assert m["shed_queue_full"] == 15 and m["completed"] == 5
    assert m["peak_queue_depth"] == 5


def test_flood_requests_seed_deterministic():
    a = FI.flood_requests(4, 256, seed=9)
    b = FI.flood_requests(4, 256, seed=9)
    assert all((x.tokens == y.tokens).all() and x.rid == y.rid
               for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# Drain status: drained / timeout / stalled
# ---------------------------------------------------------------------------
def test_drain_result_is_list_compatible(params):
    _, res = drain(params, make_requests(n=2))
    assert isinstance(res, (list, DrainResult))
    assert len(res) == 2 and res.status == "drained"
    assert res.undrained == [] and res.failed == []


def test_exhausted_max_steps_reports_timeout(params):
    """The old engine returned silently when max_steps ran out with work
    still queued — indistinguishable from a clean drain. Now it says so."""
    _, res = drain(params, make_requests(), max_steps=2)
    assert res.status == "timeout"
    assert len(res.undrained) > 0


def test_wedged_engine_trips_watchdog(params):
    """An engine that stops making progress (wedge injector) must be
    classified 'stalled' by the watchdog, not spun on forever."""
    plan = FI.FaultPlan(wedge_from_step=1, wedge_s=0.005)
    cb, res = drain(params, make_requests(), faults=plan,
                    watchdog_s=0.05)
    assert res.status == "stalled"
    assert len(res.undrained) == 6
    assert any(f.startswith("wedge@") for f in plan.fired)


def test_slow_step_still_drains(params, oracle):
    plan = FI.FaultPlan(slow_step=1, slow_s=0.02)
    cb, res = drain(params, make_requests(), faults=plan,
                    watchdog_s=5.0)
    assert res.status == "drained"
    assert_identical(res, oracle)


# ---------------------------------------------------------------------------
# Checkpoint integrity: corrupt artifacts quarantine, transient loads heal
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["bitflip", "truncate"])
def test_corrupt_artifact_quarantined(tmp_path, comp, kind):
    from repro.ckpt import store
    cfg = CFG.replace(rank_multiple=1)
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    d = str(tmp_path / kind)
    # persist the already-compressed params with a fresh plan
    _, plan = CC.build_plan_and_params(
        T.init_model(cfg, jax.random.PRNGKey(0))[0], cfg,
        CC.CompressionConfig(ratio=0.4), calib)
    CC.save_plan(d, comp, plan, cfg)
    FI.corrupt_artifact(f"{d}/{CC.ARTIFACT_NAME}", kind=kind, seed=1)
    with pytest.raises(store.IntegrityError):
        ContinuousBatcher.from_compressed(
            d, cfg, SCFG, verify=True, load_retries=1, quarantine=True)
    # the poisoned bytes were moved aside, not deleted
    assert (tmp_path / kind / f"{CC.ARTIFACT_NAME}.quarantined").exists()
    assert not (tmp_path / kind / CC.ARTIFACT_NAME).exists()


def test_swapped_data_detected_only_by_hash_verify(tmp_path, comp):
    """Corruption the zip CRC layer can NOT see: the stored arrays are
    valid bytes that simply aren't the ones the manifest hashed (silent
    replacement / wrong-file restore). A verify-less load succeeds
    silently — which is exactly why --verify exists."""
    cfg = CFG.replace(rank_multiple=1)
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    _, plan = CC.build_plan_and_params(
        T.init_model(cfg, jax.random.PRNGKey(0))[0], cfg,
        CC.CompressionConfig(ratio=0.4), calib)
    d = str(tmp_path / "art")
    CC.save_plan(d, comp, plan, cfg)
    mpath = f"{d}/{CC.ARTIFACT_NAME}/manifest.json"
    with open(mpath) as f:
        manifest = json.load(f)
    key = sorted(manifest["hashes"])[0]
    manifest["hashes"][key] = "0" * 64           # data != recorded hash
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    CC.load_plan(d, cfg=cfg, verify=False)       # loads, silently wrong
    from repro.ckpt import store
    with pytest.raises(store.IntegrityError):
        CC.load_plan(d, cfg=cfg, verify=True)


def test_transient_load_failure_retries_to_success(tmp_path, monkeypatch):
    from repro.ckpt import store
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    store.save_pytree(str(tmp_path), tree, name="pytree")
    real = store.load_pytree
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("transient I/O blip")
        return real(*a, **kw)

    monkeypatch.setattr(store, "load_pytree", flaky)
    got, _ = store.load_pytree_resilient(str(tmp_path), retries=2,
                                         backoff_s=0.001)
    assert calls["n"] == 2
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])


def test_missing_artifact_is_not_retried(tmp_path):
    """A missing directory is a config error, not corruption — it must
    fail immediately (FileNotFoundError), not burn retries/quarantine."""
    from repro.ckpt import store
    with pytest.raises(FileNotFoundError):
        store.load_pytree_resilient(str(tmp_path / "nope"), retries=3)


# ---------------------------------------------------------------------------
# Elastic-rank degradation ladder
# ---------------------------------------------------------------------------
def test_rank_bucket_values():
    assert CC.rank_bucket(24, 0) == 24
    assert CC.rank_bucket(24, 1) == 16          # pow2_ceil(24)=32 >> 1
    assert CC.rank_bucket(24, 2) == 8
    assert CC.rank_bucket(16, 1) == 8
    assert CC.rank_bucket(1, 3) == 1            # clamped at min_rank
    assert CC.rank_bucket(5, 1, min_rank=4) == 4


def test_ladder_slices_share_bases_and_alias_full_rung(comp):
    ladder = CC.slice_rank_ladder(comp, levels=2)
    assert len(ladder) == 3
    assert ladder[0] is comp                    # rung 0 IS the input tree
    full = CC.compressed_param_count(ladder[0])
    assert CC.compressed_param_count(ladder[1]) < full
    assert CC.compressed_param_count(ladder[2]) < \
        CC.compressed_param_count(ladder[1])


def test_ladder_on_dense_params_collapses(params):
    ladder = CC.slice_rank_ladder(params, levels=2)
    assert all(rung is params for rung in ladder)


def test_elastic_full_bucket_token_identical(comp):
    """With the ladder enabled but pressure never tripping degradation,
    the elastic engine is token-identical to the pre-ladder engine."""
    reqs = make_requests(n=8)
    cb0, res0 = drain(comp, [Request(rid=r.rid, tokens=r.tokens,
                                     n_new=r.n_new) for r in reqs])
    cbE, resE = drain(comp, reqs, admission=adm.AdmissionConfig(
        elastic=True, degrade_above=10**6))
    assert res0.status == resE.status == "drained"
    assert {r.rid: list(r.out) for r in resE} == \
        {r.rid: list(r.out) for r in res0}
    assert cbE.metrics()["rank_residency"].keys() == {"0"}


def test_elastic_degrades_under_pressure_deterministically(comp):
    """Queue pressure drops the decode rank (residency shows degraded
    rungs), everything still completes, and two identical runs agree on
    residency AND tokens; each rung costs exactly one decode trace."""
    runs = []
    for _ in range(2):
        cb, res = drain(comp, make_requests(n=16),
                        admission=adm.AdmissionConfig(
                            elastic=True, elastic_levels=2,
                            degrade_above=4, restore_below=1))
        assert res.status == "drained" and len(res) == 16
        m = cb.metrics()
        assert set(m["rank_residency"]) > {"0"}     # actually degraded
        assert m["engine"]["decode_retraces"] == \
            len(set(m["rank_residency"]))
        runs.append((m["rank_residency"],
                     {r.rid: list(r.out) for r in res}))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Metrics surface
# ---------------------------------------------------------------------------
def test_metrics_snapshot_schema(params):
    cb, res = drain(params, make_requests(n=3, n_new=2))
    m = cb.metrics()
    for key in ("submitted", "accepted", "completed", "shed_queue_full",
                "shed_deadline", "poison_events", "poison_failures",
                "slot_purges", "steps", "peak_queue_depth", "queue_depth",
                "rank_level", "rank_residency", "ttft", "queue_wait",
                "engine"):
        assert key in m, key
    assert m["submitted"] == m["accepted"] == m["completed"] == 3
    assert m["ttft"]["n"] == 3 and m["ttft"]["p95_ms"] >= 0
    assert json.dumps(m)                        # JSON-serializable as-is
