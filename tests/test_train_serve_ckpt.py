"""Training loop, checkpoint store, serving engine, PowerSGD compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ckpt import store
from repro.data.synthetic import (DataConfig, ShardedLoader,
                                  calibration_batches)
from repro.models import transformer as T
from repro.optim import powersgd as PS
from repro.optim.adamw import OptimizerConfig
from repro.serve.engine import (ContinuousBatcher, Engine, Request,
                                ServeConfig)
from repro.train import step as TS
from repro.train.loop import LoopConfig, Trainer


CFG = get_config("llama-mini").replace(n_layers=2, d_model=64, n_heads=4,
                                       n_kv_heads=2, head_dim=16, d_ff=128,
                                       vocab_size=256)


def _dcfg(**kw):
    d = dict(vocab_size=CFG.vocab_size, seq_len=32, global_batch=4, seed=3)
    d.update(kw)
    return DataConfig(**d)


# ---------------------------------------------------------------------------
# data determinism / elasticity
# ---------------------------------------------------------------------------
def test_loader_elastic_determinism():
    dcfg = _dcfg()
    full = ShardedLoader(dcfg).batch(7)["tokens"]
    parts = [ShardedLoader(dcfg, i, 2).batch(7)["tokens"] for i in range(2)]
    assert (np.concatenate(parts) == full).all()
    # different steps differ
    assert not (ShardedLoader(dcfg).batch(8)["tokens"] == full).all()


def test_calibration_disjoint_from_training():
    dcfg = _dcfg()
    calib = calibration_batches(dcfg, n_samples=4, batch_size=4)
    train = ShardedLoader(dcfg).batch(0)["tokens"]
    assert not (calib[0]["tokens"] == train).all()


# ---------------------------------------------------------------------------
# trainer: loss decreases, checkpoint-resume is bit-exact
# ---------------------------------------------------------------------------
def test_trainer_descends_and_resumes(tmp_path):
    tcfg = TS.TrainConfig(optimizer=OptimizerConfig(
        lr=5e-3, warmup_steps=5, total_steps=60))
    lcfg = LoopConfig(total_steps=30, ckpt_dir=str(tmp_path / "ck"),
                      ckpt_every=10, log_every=5)
    tr = Trainer(CFG, tcfg, _dcfg(), lcfg, seed=0)
    out = tr.run()
    assert out["final_step"] == 30
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0]

    # continuous run to 40
    lcfg2 = LoopConfig(total_steps=40, ckpt_dir=str(tmp_path / "ck2"),
                       ckpt_every=100, log_every=5)
    tr_full = Trainer(CFG, tcfg, _dcfg(), lcfg2, seed=0)
    full = tr_full.run()

    # resumed run 30 -> 40 from the first job's checkpoint
    lcfg3 = LoopConfig(total_steps=40, ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=100, log_every=5)
    tr_res = Trainer(CFG, tcfg, _dcfg(), lcfg3, seed=0)
    assert tr_res.start_step == 30
    res = tr_res.run()
    # same data (counter-based) + same state => identical final loss
    assert res["history"][-1]["loss"] == pytest.approx(
        full["history"][-1]["loss"], rel=1e-4)


def test_ckpt_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    store.save(str(tmp_path), 5, tree)
    store.save(str(tmp_path), 9, jax.tree.map(lambda x: x * 2, tree))
    assert store.latest_step(str(tmp_path)) == 9
    step, back = store.restore(str(tmp_path), tree)
    assert step == 9
    assert jnp.allclose(back["a"], tree["a"] * 2)
    assert back["b"]["c"].dtype == jnp.bfloat16
    # keep_last pruning
    for s in (11, 12, 13):
        store.save(str(tmp_path), s, tree, keep_last=2)
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(names) == 2


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path), keep_last=2)
    tree = {"x": jnp.ones((8, 8))}
    ck.submit(1, tree)
    ck.submit(2, tree)
    ck.close()
    assert store.latest_step(str(tmp_path)) == 2


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def trained_mini():
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return params


def test_engine_generate_matches_decode(trained_mini):
    eng = Engine(trained_mini, CFG, ServeConfig(temperature=0.0))
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % CFG.vocab_size
    out = eng.generate(prompts, n_new=5)
    assert out.shape == (2, 5)
    # greedy continuation must match argmax of full forward, step by step
    toks = jnp.asarray(prompts)
    for t in range(5):
        logits, _ = T.forward(trained_mini, CFG, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        assert (np.asarray(nxt) == out[:, t]).all(), t
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)


def test_continuous_batcher_matches_sequential(trained_mini):
    scfg = ServeConfig(batch=3, max_len=64, temperature=0.0)
    cb = ContinuousBatcher(trained_mini, CFG, scfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, CFG.vocab_size, size=(4 + i,),
                                        dtype=np.int32),
                    n_new=6) for i in range(5)]
    for r in reqs:
        cb.submit(r)
    done = cb.run_until_drained()
    assert len(done) == 5
    eng = Engine(trained_mini, CFG, ServeConfig(temperature=0.0))
    for r in done:
        ref = eng.generate(r.tokens[None, :], n_new=6)[0]
        assert (np.asarray(r.out) == ref).all(), (r.rid, r.out, ref)


def test_throughput_meter(trained_mini):
    eng = Engine(trained_mini, CFG, ServeConfig())
    m = eng.measure_decode_throughput(batch=2, prompt_len=8, n_new=4)
    assert m["tokens_per_s"] > 0


# ---------------------------------------------------------------------------
# PowerSGD
# ---------------------------------------------------------------------------
def test_powersgd_identity_at_full_rank():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 16))}
    cfg = PS.PowerSGDConfig(rank=16, min_dim=8)
    st = PS.init_state(g, cfg)
    out, st2, stats = PS.compress_decompress(g, st, cfg)
    # rank == min(dim): exact after one power iteration? not exact, but EF
    # residual shrinks over repeated rounds on a FIXED gradient
    errs = []
    for _ in range(6):
        out, st, stats = PS.compress_decompress(g, st, cfg)
        errs.append(float(jnp.linalg.norm(out["w"] - g["w"])))
    assert errs[-1] < errs[0] * 0.5


def test_powersgd_error_feedback_preserves_mean_signal():
    """With EF, the time-averaged decompressed gradient tracks the true
    gradient much better than without EF (the EF telescoping sum)."""
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, (64, 64))
    T = 40

    def mean_err(ef: bool) -> float:
        cfg = PS.PowerSGDConfig(rank=2, min_dim=8, ef=ef)
        st = PS.init_state({"w": g}, cfg)
        acc = jnp.zeros_like(g)
        for _ in range(T):
            out, st, _ = PS.compress_decompress({"w": g}, st, cfg)
            acc = acc + out["w"]
        return float(jnp.linalg.norm(acc / T - g) / jnp.linalg.norm(g))

    assert mean_err(True) < 0.7 * mean_err(False)


def test_powersgd_byte_reduction_stats():
    g = {"w": jnp.ones((256, 256))}
    cfg = PS.PowerSGDConfig(rank=4, min_dim=8)
    st = PS.init_state(g, cfg)
    _, _, stats = PS.compress_decompress(g, st, cfg)
    assert stats["byte_reduction"] > 20     # 256²/(4·512) = 32


def test_powersgd_reff_rank_allocation():
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    lowrank_g = jax.random.normal(ks[0], (64, 4)) @ \
        jax.random.normal(ks[1], (4, 64))
    fullrank_g = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    g = {"low": lowrank_g, "high": fullrank_g}
    cfg = PS.PowerSGDConfig(rank=4, min_dim=8)
    ranks = PS.allocate_ranks_by_reff(g, byte_budget_frac=0.2, cfg=cfg)
    assert ranks["['high']"] > ranks["['low']"]
