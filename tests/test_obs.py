"""Observability layer (DESIGN.md §6): tracing spans + Chrome-trace
export, the typed metrics registry with bounded reservoirs, and the
flight recorder.

The contracts under test:

* disabled tracing is a shared no-op singleton (zero allocation on the
  hot path — asserted by identity);
* an enabled trace is valid Chrome-trace JSON (schema-checked with the
  same validator the CI chaos drill uses) and thread-safe under the
  front door's engine thread;
* the event ORDER on the engine thread is deterministic under a seeded
  ``FaultPlan`` (timestamps vary, sequence does not);
* metric reservoirs are bounded (the pre-v2 per-request lists grew
  forever) and the percentile helpers are exact on 0 and 1 samples;
* a typed poison failure auto-dumps a flight-recorder artifact that
  identifies the poisoned rid and the rung it failed at.
"""
import json
import threading
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist import faultinject as FI
from repro.models import transformer as T
from repro.obs import flightrec, metrics, trace
from repro.serve import admission as adm
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig
from repro.serve.frontdoor import FrontDoor

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
SCFG = ServeConfig(batch=4, max_len=64)


@pytest.fixture(scope="module")
def params():
    p, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return p


def make_requests(n=6, n_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, n_new=n_new,
                    tokens=rng.integers(0, CFG.vocab_size, size=(7,),
                                        dtype=np.int32))
            for i in range(n)]


# ---------------------------------------------------------------------------
# trace: disabled fast path
# ---------------------------------------------------------------------------

def test_disabled_span_is_the_shared_singleton():
    """While tracing is off every span() call returns the SAME no-op
    object — the disabled hot path allocates nothing."""
    assert not trace.enabled()
    s1 = trace.span("decode_step", step=1)
    s2 = trace.span("anything_else")
    assert s1 is trace.NULL_SPAN and s2 is trace.NULL_SPAN
    with s1:
        pass                                  # context protocol still works
    # instants/counters/async events are no-ops, not errors
    trace.instant("x")
    trace.counter("x", v=1)
    trace.async_begin("x", 1)
    trace.async_end("x", 1)
    assert trace.current() is None


def test_enabled_spans_are_real_and_disable_restores():
    t = trace.enable()
    try:
        assert trace.span("s") is not trace.NULL_SPAN
        with trace.span("s", k=1):
            pass
        assert any(e["name"] == "s" for e in t.events)
    finally:
        assert trace.disable() is t
    assert trace.span("s") is trace.NULL_SPAN


# ---------------------------------------------------------------------------
# trace: Chrome-trace schema
# ---------------------------------------------------------------------------

def test_chrome_trace_export_is_schema_valid(tmp_path):
    out = tmp_path / "t.json"
    with trace.tracing(out=str(out)) as t:
        with trace.span("outer", a=1):
            with trace.span("inner"):
                pass
        trace.instant("blip", why="test")
        trace.counter("serve", queue_depth=3)
        trace.async_begin("request", 7, n_new=5)
        trace.async_end("request", 7, status="done")
    obj = json.loads(out.read_text())
    assert trace.validate_chrome_trace(obj) == []
    assert obj["displayTimeUnit"] == "ms"
    assert obj["otherData"]["schema"] == trace.SCHEMA
    evs = obj["traceEvents"]
    names = [e["name"] for e in evs]
    assert {"outer", "inner", "blip", "serve", "request"} <= set(names)
    # nesting: inner closed before outer, both X spans, inner within outer
    inner = next(e for e in evs if e["name"] == "inner")
    outer = next(e for e in evs if e["name"] == "outer")
    assert inner["ph"] == outer["ph"] == "X"
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    # the emitting thread got an M metadata name event
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)
    assert t.dropped == 0


def test_validator_flags_malformed_events():
    assert trace.validate_chrome_trace({}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "Z", "pid": 1, "tid": 1},
                           {"name": "", "ph": "i", "pid": 1, "tid": 1,
                            "ts": 0.0},
                           {"name": "x", "ph": "X", "pid": 1, "tid": 1,
                            "ts": 0.0, "dur": -5},
                           {"name": "x", "ph": "b", "pid": 1, "tid": 1,
                            "ts": 0.0}]}
    errs = trace.validate_chrome_trace(bad)
    assert len(errs) == 4


def test_tracer_bounds_memory_and_counts_drops():
    t = trace.Tracer(max_events=4)
    trace.enable(t)
    try:
        for i in range(10):
            with trace.span("s", i=i):
                pass
    finally:
        trace.disable()
    assert len(t.events) <= 4
    assert t.dropped > 0
    assert t.to_chrome()["otherData"]["dropped_events"] == t.dropped


def test_trace_is_thread_safe_under_concurrent_emitters():
    t = trace.enable()
    try:
        barrier = threading.Barrier(4)     # distinct tids: all live at once

        def emit(k):
            barrier.wait()
            for i in range(50):
                with trace.span(f"w{k}", i=i):
                    pass
        threads = [threading.Thread(target=emit, args=(k,), name=f"w{k}")
                   for k in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        trace.disable()
    assert trace.validate_chrome_trace(t.to_chrome()) == []
    spans = [e for e in t.events if e["ph"] == "X"]
    assert len(spans) == 200
    # every emitting thread self-registered a name metadata event
    meta = {e["args"]["name"] for e in t.events if e["ph"] == "M"}
    assert {f"w{k}" for k in range(4)} <= meta
    # seq is strictly monotonic in insertion order (the determinism key)
    seqs = [e["seq"] for e in t.events if e["seq"] >= 0]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)


# ---------------------------------------------------------------------------
# trace: deterministic ordering under a seeded FaultPlan
# ---------------------------------------------------------------------------

def _traced_run(params, plan_json):
    faults = FI.FaultPlan.from_json(plan_json) if plan_json else None
    with trace.tracing() as t:
        cb = ContinuousBatcher(
            params, CFG, SCFG,
            admission=adm.AdmissionConfig(max_retries=1), faults=faults)
        for r in make_requests():
            cb.submit(r)
        res = cb.run_until_drained()
    # the comparable fingerprint: names + the deterministic args, in
    # seq order (timestamps/durations differ run to run by design)
    evs = sorted((e for e in t.events if e["seq"] >= 0),
                 key=lambda e: e["seq"])
    sig = [(e["name"], e["ph"], json.dumps(e.get("args", {}),
                                           sort_keys=True)) for e in evs]
    return sig, res.status


def test_event_order_is_deterministic_under_seeded_faultplan(params):
    plan = json.dumps({"seed": 11, "nan_decode_step": 2,
                       "poison_rids": [3]})
    sig1, st1 = _traced_run(params, plan)
    sig2, st2 = _traced_run(params, plan)
    assert st1 == st2
    assert sig1 == sig2
    # and the faulted trace differs from the clean one (the spans see
    # the injected quarantine path)
    sig0, _ = _traced_run(params, "")
    assert sig0 != sig1


def test_frontdoor_engine_thread_traces_cleanly(params):
    with trace.tracing() as t:
        cb = ContinuousBatcher(params, CFG, SCFG)
        door = FrontDoor(cb).start()
        streams = [door.submit(r.tokens, r.n_new, rid=r.rid)
                   for r in make_requests(4)]
        assert all(s is not None for s in streams)
        res = door.drain(timeout=60.0)
        door.close()
    assert res.status == "drained"
    assert trace.validate_chrome_trace(t.to_chrome()) == []
    meta = {e["args"]["name"] for e in t.events if e["ph"] == "M"}
    assert "serve-engine" in meta


# ---------------------------------------------------------------------------
# metrics: bounded reservoirs, exact edge cases
# ---------------------------------------------------------------------------

def test_histogram_zero_and_one_sample_are_exact():
    h = metrics.Histogram("h")
    assert h.summary() == {"p50": 0.0, "p95": 0.0, "mean": 0.0, "n": 0,
                           "min": 0.0, "max": 0.0}
    assert h.percentile(50) == 0.0
    h.observe(42.0)
    s = h.summary()
    assert s["p50"] == s["p95"] == s["mean"] == 42.0
    assert s["n"] == 1 and s["min"] == s["max"] == 42.0


def test_histogram_is_bounded_with_exact_aggregates():
    h = metrics.Histogram("ttft_ms", capacity=64)
    for i in range(10_000):
        h.observe(float(i))
    assert len(h.samples) == 64            # memory stays O(capacity)
    assert h.n == 10_000                   # ...but n/sum/min/max are exact
    assert h.sum == sum(range(10_000))
    assert h.min == 0.0 and h.max == 9999.0
    # uniform reservoir: p50 lands around the true median
    assert 2000 < h.percentile(50) < 8000


def test_histogram_reservoir_is_deterministic_per_name():
    def fill(name):
        h = metrics.Histogram(name, capacity=16)
        for i in range(1000):
            h.observe(float(i))
        return list(h.samples)
    assert fill("a") == fill("a")          # same name+stream → same state
    assert fill("a") != fill("b")          # name seeds the RNG


def test_servemetrics_memory_is_bounded():
    """Regression: ttft/queue-wait used to be unbounded per-request
    lists; now 100k observations hold at the reservoir capacity."""
    m = adm.ServeMetrics()
    for _ in range(100_000):
        m.observe_ttft(0.01)
    assert len(m._ttft.samples) <= metrics.DEFAULT_RESERVOIR
    snap = m.snapshot(0, 0)
    assert snap["ttft"]["n"] == 100_000
    assert snap["ttft"]["p50_ms"] == pytest.approx(10.0)


def test_snapshot_v2_schema_with_legacy_aliases():
    m = adm.ServeMetrics()
    m.bump("submitted", 3)
    m.observe_ttft(0.002)
    m.step_at_level(1)
    snap = m.snapshot(queue_depth=2, rank_level=1,
                      engine_stats={"prefill_retraces": 4})
    json.dumps(snap)                       # JSON-serializable as-is
    assert snap["schema"] == metrics.SCHEMA
    # v2 blocks: typed counters (engine stats folded in), gauges, hists
    assert snap["counters"]["submitted"] == 3
    assert snap["counters"]["prefill_retraces"] == 4
    assert snap["gauges"]["queue_depth"] == 2
    assert snap["histograms"]["ttft_ms"]["n"] == 1
    assert snap["rank_residency"] == {"1": 1}
    # deprecated aliases: every pre-v2 top-level key still present
    assert snap["submitted"] == 3
    assert snap["queue_depth"] == 2 and snap["rank_level"] == 1
    assert snap["ttft"] == {"p50_ms": 2.0, "p95_ms": 2.0, "mean_ms": 2.0,
                            "n": 1}
    assert snap["queue_wait"]["n"] == 0
    assert snap["engine"] == {"prefill_retraces": 4}


def test_prometheus_text_exposition():
    r = metrics.MetricsRegistry()
    r.counter("steps").inc(7)
    r.gauge("queue_depth").set(3)
    r.histogram("ttft_ms").observe(5.0)
    text = metrics.prometheus_text(r.snapshot(), labels={"replica": "0"})
    assert '# TYPE repro_steps_total counter' in text
    assert 'repro_steps_total{replica="0"} 7' in text
    assert 'repro_queue_depth{replica="0"} 3' in text
    assert 'repro_ttft_ms{quantile="0.5",replica="0"} 5.0' in text
    assert 'repro_ttft_ms_count{replica="0"} 1' in text


def test_metrics_exporter_and_server(tmp_path):
    r = metrics.MetricsRegistry()
    r.counter("steps").inc(2)
    path = tmp_path / "m.json"
    exp = metrics.MetricsExporter(str(path), r.snapshot, interval_s=60.0)
    exp.start()
    exp.stop()                             # final write even if no tick
    snap = json.loads(path.read_text())
    assert snap["schema"] == metrics.SCHEMA
    assert snap["counters"]["steps"] == 2
    srv = metrics.MetricsServer(lambda: [r.snapshot()], port=0).start()
    try:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=10).read()
    finally:
        srv.stop()
    assert b'repro_steps_total{replica="0"} 2' in body


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flightrec_ring_is_bounded_and_dump_validates(tmp_path):
    fr = flightrec.FlightRecorder(dump_dir=str(tmp_path), max_events=8,
                                  max_timings=4)
    for i in range(50):
        fr.note("tick", i=i)
        fr.step_timing(i, 1.5, live=2)
    assert len(fr.events) == 8 and len(fr.step_timings) == 4
    path = fr.dump("stalled", {"queue_depth": 3})
    obj = json.loads(open(path).read())
    assert flightrec.validate_dump(obj) == []
    assert obj["reason"] == "stalled"
    assert obj["context"]["queue_depth"] == 3
    assert [e["i"] for e in obj["events"]] == list(range(42, 50))
    # validator catches a corrupted artifact
    obj["schema"] = "nope"
    obj["events"] = obj["events"][::-1]
    assert len(flightrec.validate_dump(obj)) == 2


def test_flightrec_without_dump_dir_records_but_never_writes():
    fr = flightrec.FlightRecorder()
    fr.note("x")
    assert fr.dump("stalled") is None and fr.dumps == []
    assert len(fr.events) == 1


def test_poison_failure_autodumps_identifying_rid_and_rung(
        params, tmp_path):
    """The acceptance artifact: an injected persistent poison fails
    typed AND leaves a dump from which the poisoned rid, the rung it
    failed at and the armed plan (seed included) are all recoverable."""
    plan = FI.FaultPlan.from_json(
        json.dumps({"seed": 5, "poison_rids": [2]}))
    cb = ContinuousBatcher(
        params, CFG, SCFG,
        admission=adm.AdmissionConfig(max_retries=1), faults=plan,
        flight=flightrec.FlightRecorder(dump_dir=str(tmp_path)))
    for r in make_requests():
        cb.submit(r)
    res = cb.run_until_drained()
    assert res.status == "drained"
    assert [r.rid for r in res.failed] == [2]
    assert len(cb.flight.dumps) == 1
    obj = json.loads(open(cb.flight.dumps[0]).read())
    assert flightrec.validate_dump(obj) == []
    assert obj["reason"] == "failed_poison"
    assert obj["context"]["rid"] == 2
    assert obj["context"]["rank_level"] == 0
    assert obj["context"]["fault_plan"]["seed"] == 5
    assert obj["context"]["fault_plan"]["poison_rids"] == [2]
    assert any(e["kind"] == "poison" and 2 in e["rids"]
               for e in obj["events"])
    assert obj["step_timings"]           # last-N step wall times present


def test_nondrained_drain_autodumps(params, tmp_path):
    plan = FI.FaultPlan.from_json(json.dumps({"wedge_from_step": 0,
                                              "wedge_s": 0.0}))
    cb = ContinuousBatcher(
        params, CFG, SCFG, faults=plan,
        flight=flightrec.FlightRecorder(dump_dir=str(tmp_path)))
    for r in make_requests(2):
        cb.submit(r)
    res = cb.run_until_drained(watchdog_s=0.2)
    assert res.status == "stalled"
    assert len(cb.flight.dumps) == 1
    obj = json.loads(open(cb.flight.dumps[0]).read())
    assert obj["reason"] == "stalled"
    assert sorted(obj["context"]["undrained_rids"]) == [0, 1]
