"""ISSUE 7 front-door coverage: per-token streaming must carry exactly
the drained output, intake backpressure must reject before the engine is
ever involved, and the router must place requests deterministically by
replica load and spill on pushback.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import admission as adm
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig
from repro.serve.frontdoor import FrontDoor, Router, merge_drain_results

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
SCFG = ServeConfig(batch=2, max_len=32)


@pytest.fixture(scope="module")
def params():
    p, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return p


def _prompts(n, seed=0, length=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=(length,), dtype=np.int32)
            for _ in range(n)]


def _oracle(params, prompts, n_new=5):
    cb = ContinuousBatcher(params, CFG, SCFG)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, tokens=p.copy(), n_new=n_new))
    res = cb.run_until_drained()
    assert res.status == "drained"
    return {r.rid: list(r.out) for r in res}


def test_streamed_tokens_equal_drained_tokens(params):
    prompts = _prompts(6)
    oracle = _oracle(params, prompts)
    fd = FrontDoor(ContinuousBatcher(params, CFG, SCFG)).start()
    streams = [fd.submit(p, 5, rid=i) for i, p in enumerate(prompts)]
    assert all(s is not None for s in streams)
    # iterate BEFORE drain: tokens must arrive as they are emitted
    collected = [[t for t in s] for s in streams]
    res = fd.drain(timeout=120)
    fd.close()
    assert res.status == "drained" and len(res) == len(prompts)
    for i, s in enumerate(streams):
        assert s.status == adm.DONE
        assert collected[i] == oracle[i]       # the live stream
        assert s.tokens() == oracle[i]         # the terminal snapshot
        assert s.result(1).rid == i
        assert s.rewinds == 0


def test_intake_backpressure_rejects_before_the_engine(params):
    fd = FrontDoor(ContinuousBatcher(params, CFG, SCFG), intake_bound=2)
    # engine thread NOT started: the bound is the only admission control
    assert fd.submit(_prompts(1)[0], 2, rid=0) is not None
    assert fd.submit(_prompts(1)[0], 2, rid=1) is not None
    assert fd.submit(_prompts(1)[0], 2, rid=2) is None    # full intake
    assert fd.load() == 2


def test_admission_rejects_surface_as_terminal_streams(params):
    acfg = adm.AdmissionConfig(max_queue=1)
    fd = FrontDoor(ContinuousBatcher(params, CFG, SCFG, admission=acfg),
                   intake_bound=16)
    prompts = _prompts(6, seed=3)
    streams = [fd.submit(p, 3, rid=i) for i, p in enumerate(prompts)]
    assert all(s is not None for s in streams)  # intake took everything
    fd.start()
    res = fd.drain(timeout=120)
    fd.close()
    # every stream reached a terminal state — sheds included, so a
    # client blocked on result() is never left hanging
    for s in streams:
        assert s.result(1).status in (adm.DONE, adm.SHED_QUEUE_FULL)
    shed = [s for s in streams if s.status == adm.SHED_QUEUE_FULL]
    assert len(shed) == len(res.rejected)
    assert len(res) + len(shed) == len(prompts)


def test_router_balances_by_load_and_spills_on_pushback(params):
    doors = [FrontDoor(ContinuousBatcher(params, CFG, SCFG),
                       intake_bound=4) for _ in range(2)]
    router = Router(doors)
    prompts = _prompts(8, seed=1)
    streams = [router.submit(p, 2) for p in prompts]
    assert all(s is not None for s in streams)
    # engines not started yet: load == intake depth, so placement is the
    # deterministic least-loaded alternation 4/4
    assert [d.load() for d in doors] == [4, 4]
    # both intakes full -> every replica pushes back -> None
    assert router.submit(prompts[0], 2) is None
    router.start()
    res = router.drain_all(timeout=120)
    router.close()
    assert res.status == "drained" and len(res) == len(prompts)
    oracle = _oracle(params, prompts, n_new=2)
    got = sorted([s.tokens() for s in streams])
    assert got == sorted(oracle.values())


def test_merge_drain_results_takes_worst_status():
    a = type("R", (), {})  # stand-in rows are fine; merge only concatenates
    from repro.serve.engine import DrainResult
    r1 = DrainResult([a], "drained", [], [], [], [])
    r2 = DrainResult([a, a], "timeout", [a], [], [], [])
    m = merge_drain_results([r1, r2])
    assert m.status == "timeout"
    assert len(m) == 3 and len(m.undrained) == 1
    assert merge_drain_results([]).status == "drained"
