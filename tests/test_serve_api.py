"""ISSUE 7 API-redesign coverage: the typed ``repro.serve.api`` surface,
the CLI↔ServeOptions golden round trip (every legacy flag maps; the
deprecated spellings warn), and the unified ``from_compressed`` factory.
"""
import dataclasses
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.core import compress as CC
from repro.models import transformer as T
from repro.serve import api
from repro.launch.serve import build_parser, parse_serve_options

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)


# ---------------------------------------------------------------------------
# API surface snapshot
# ---------------------------------------------------------------------------

API_SURFACE = [
    "AotCache", "AotRegistry", "ContinuousBatcher", "DrainResult",
    "Engine", "FrontDoor", "Request", "Router", "ServeConfig",
    "ServeOptions", "TokenStream", "TracedRegistry", "from_compressed",
    "load_engine", "serve",
]


def test_api_surface_snapshot():
    """The public surface is a contract: additions mean updating this
    snapshot deliberately; removals/renames are breaking changes."""
    assert sorted(api.__all__) == API_SURFACE
    for name in api.__all__:
        assert getattr(api, name) is not None


# ---------------------------------------------------------------------------
# CLI <-> ServeOptions golden round trip
# ---------------------------------------------------------------------------

# every flag the CLI accepts, with a non-default value, and the
# ServeOptions field it must land in — the golden map. A flag missing
# here (or a field missing a flag) fails the completeness checks below.
GOLDEN = [
    # (argv fragment, field, expected value)
    (["--arch", "llama-mini"], "arch", "llama-mini"),
    (["--ckpt", "runs/x"], "ckpt", "runs/x"),
    (["--compress", "drank"], "compress", "drank"),
    (["--ratio", "0.4"], "ratio", 0.4),
    (["--group-size", "4"], "group_size", 4),
    (["--beta", "0.7"], "beta", 0.7),
    (["--save-compressed", "runs/cc"], "save_compressed", "runs/cc"),
    (["--verify"], "verify", True),
    (["--calib-mesh-shards", "2"], "calib_mesh_shards", 2),
    (["--shard-grams-above", "128"], "shard_grams_above", 128),
    (["--calib-samples", "32"], "calib_samples", 32),
    (["--calib-seq", "64"], "calib_seq", 64),
    (["--device-compress"], "device_compress", True),
    (["--rsvd-threshold", "96"], "rsvd_threshold", 96),
    (["--batch", "3"], "batch", 3),
    (["--max-len", "128"], "max_len", 128),
    (["--kv-block", "16"], "kv_block", 16),
    (["--prefix-cache"], "prefix_cache", True),
    (["--requests", "5"], "requests", 5),
    (["--prompt-len", "9"], "prompt_len", 9),
    (["--n-new", "11"], "n_new", 11),
    (["--seed", "7"], "seed", 7),
    (["--max-queue", "6"], "max_queue", 6),
    (["--deadline-s", "12.5"], "deadline_s", 12.5),
    (["--max-retries", "3"], "max_retries", 3),
    (["--reject-overlong"], "reject_overlong", True),
    (["--elastic"], "elastic", True),
    (["--elastic-levels", "1"], "elastic_levels", 1),
    (["--watchdog-s", "45"], "watchdog_s", 45.0),
    (["--heartbeat-dir", "runs/hb"], "heartbeat_dir", "runs/hb"),
    (["--fault-plan", '{"nan_decode_step": 3}'], "fault_plan",
     '{"nan_decode_step": 3}'),
    (["--load-retries", "2"], "load_retries", 2),
    (["--stats-json", "runs/s.json"], "stats_json", "runs/s.json"),
    (["--aot"], "aot", True),
    (["--aot-cache-dir", "/tmp/aot"], "aot_cache_dir", "/tmp/aot"),
    (["--replicas", "2"], "replicas", 2),
    (["--stream"], "stream", True),
    (["--trace-out", "runs/t.json"], "trace_out", "runs/t.json"),
    (["--device-trace-dir", "runs/prof"], "device_trace_dir",
     "runs/prof"),
    (["--metrics-json", "runs/m.json"], "metrics_json", "runs/m.json"),
    (["--metrics-interval-s", "0.5"], "metrics_interval_s", 0.5),
    (["--metrics-port", "0"], "metrics_port", 0),
    (["--flightrec-dir", "runs/frec"], "flightrec_dir", "runs/frec"),
]
# flags that exist but map through translation, or cannot combine with
# the all-at-once argv below
SPECIAL = {
    "--slots": "batch",                    # deprecated alias
    "--whiten-stream": "whiten_stream",    # conflicts with --eager-capture
    "--eager-capture": "eager_capture",    # conflicts with mesh shards
    "--compressed-ckpt": "compressed_ckpt",  # conflicts with --compress
}


def test_every_flag_round_trips_together():
    argv = [tok for frag, _, _ in GOLDEN for tok in frag]
    opts = parse_serve_options(argv)
    for _, field, want in GOLDEN:
        assert getattr(opts, field) == want, field


def test_conflicting_flags_round_trip_individually():
    opts = parse_serve_options(["--arch", "llama-mini", "--whiten-stream"])
    assert opts.whiten_stream is True
    opts = parse_serve_options(["--arch", "llama-mini", "--eager-capture"])
    assert opts.eager_capture is True
    opts = parse_serve_options(["--arch", "llama-mini",
                                "--compressed-ckpt", "runs/cc"])
    assert opts.compressed_ckpt == "runs/cc"


def test_golden_map_is_complete_both_ways():
    """No CLI flag and no ServeOptions field outside the golden map."""
    parser_flags = {a.option_strings[0] for a in build_parser()._actions
                    if a.option_strings and a.option_strings[0] != "-h"}
    golden_flags = {frag[0] for frag, _, _ in GOLDEN} | set(SPECIAL)
    assert parser_flags == golden_flags
    fields = set(api.ServeOptions.__dataclass_fields__)
    golden_fields = {f for _, f, _ in GOLDEN} | set(SPECIAL.values())
    assert fields == golden_fields


def test_slots_is_a_deprecated_alias_of_batch():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        opts = parse_serve_options(["--arch", "llama-mini", "--slots", "3"])
    assert opts.batch == 3
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    # explicit --batch wins over the alias
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        opts = parse_serve_options(["--arch", "llama-mini",
                                    "--slots", "3", "--batch", "5"])
    assert opts.batch == 5


def test_cli_rejects_bad_combinations_as_parse_errors():
    with pytest.raises(SystemExit):
        parse_serve_options(["--arch", "llama-mini", "--whiten-stream",
                             "--eager-capture"])


# ---------------------------------------------------------------------------
# ServeOptions validation
# ---------------------------------------------------------------------------

def test_options_validate_at_construction():
    ok = api.ServeOptions(arch="llama-mini")
    assert ok.serve_config().batch == ok.batch
    assert ok.admission_config().max_retries == ok.max_retries
    with pytest.raises(ValueError, match="unknown compression"):
        api.ServeOptions(arch="llama-mini", compress="zip")
    with pytest.raises(ValueError, match="conflict"):
        api.ServeOptions(arch="llama-mini", compress="drank",
                         compressed_ckpt="runs/cc")
    with pytest.raises(ValueError, match="save_compressed"):
        api.ServeOptions(arch="llama-mini", save_compressed="runs/cc")
    with pytest.raises(ValueError, match="streaming capture"):
        api.ServeOptions(arch="llama-mini", whiten_stream=True,
                         eager_capture=True)
    with pytest.raises(ValueError, match="must divide"):
        api.ServeOptions(arch="llama-mini", calib_mesh_shards=3)
    with pytest.raises(ValueError, match="multiple"):
        api.ServeOptions(arch="llama-mini", calib_mesh_shards=2,
                         calib_samples=12)
    with pytest.raises(ValueError, match="replicas"):
        api.ServeOptions(arch="llama-mini", replicas=0)
    with pytest.raises(ValueError, match="metrics_port"):
        api.ServeOptions(arch="llama-mini", metrics_port=70000)
    with pytest.raises(ValueError, match="metrics_interval_s"):
        api.ServeOptions(arch="llama-mini", metrics_interval_s=0.0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        ok.batch = 9


# ---------------------------------------------------------------------------
# unified from_compressed factory
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("api_artifact"))
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    cfg = CFG.replace(rank_multiple=1)
    comp, plan = CC.build_plan_and_params(
        params, cfg, CC.CompressionConfig(ratio=0.4), calib)
    CC.save_plan(d, comp, plan, cfg)
    return d, cfg


def test_unified_factory_returns_both_kinds(artifact):
    d, cfg = artifact
    scfg = api.ServeConfig(batch=2, max_len=32)
    cb = api.from_compressed(d, cfg, scfg)
    eng = api.from_compressed(d, cfg, scfg, batcher=False)
    assert isinstance(cb, api.ContinuousBatcher)
    assert isinstance(eng, api.Engine)
    assert not isinstance(eng, api.ContinuousBatcher)
    # the shared loading path attaches the plan on both
    assert cb.plan.summary == eng.plan.summary
    # and the classmethods stay as thin delegates of the same factory
    cb2 = api.ContinuousBatcher.from_compressed(d, cfg, scfg)
    eng2 = api.Engine.from_compressed(d, cfg, scfg)
    assert isinstance(cb2, api.ContinuousBatcher)
    assert type(eng2) is api.Engine


def test_factory_retries_kwarg_is_deprecated(artifact):
    d, cfg = artifact
    scfg = api.ServeConfig(batch=2, max_len=32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = api.Engine.from_compressed(d, cfg, scfg, retries=0)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert isinstance(eng, api.Engine)
