"""Prefill + token-by-token decode must reproduce the full-sequence forward
logits — for every cache type (full KV, sliding-window ring, mLSTM state,
mamba/SSD state, enc-dec cross-attention)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import transformer as T

from conftest import make_batch

# llama-mini: full KV.  gemma3: local/global mix + ring buffer + geglu.
# hymba: parallel attn+ssm, ring + state.  xlstm: pure state.
# seamless: enc-dec cross attention.  granite: MoE decode.
ARCHS = ["llama-mini", "gemma3-12b", "hymba-1.5b", "xlstm-350m",
         "seamless-m4t-medium", "granite-moe-1b-a400m", "qwen3-4b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    # ring buffers only exercise wraparound if seq > window
    S, split = 24, 12
    batch = make_batch(cfg, jax.random.fold_in(rng, 3), batch=2, seq=S)
    params, _ = T.init_model(cfg, rng)

    full_logits, _ = T.forward(params, cfg, batch)

    prompt = {k: (v[:, :split] if k in ("tokens", "embeds") else v)
              for k, v in batch.items()}
    lp, cache = T.prefill(params, cfg, prompt, max_len=S + 8)
    outs = [lp]
    stream = batch.get("tokens")
    for t in range(split, S):
        tok = stream[:, t:t + 1]
        lg, cache = T.decode_step(params, cfg, cache, tok)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)

    ref = full_logits[:, split - 1:S]
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 2e-3, err


@pytest.mark.slow           # ~80s: longest single test (3× window decode)
def test_decode_window_wraparound(rng):
    """Sliding-window ring cache stays exact long past the window size."""
    cfg = get_config("gemma3-12b").reduced()
    assert cfg.sliding_window == 8
    S = 4 * cfg.sliding_window
    batch = make_batch(cfg, jax.random.fold_in(rng, 4), batch=1, seq=S)
    params, _ = T.init_model(cfg, rng)
    full_logits, _ = T.forward(params, cfg, batch)

    lp, cache = T.prefill(params, cfg,
                          {"tokens": batch["tokens"][:, :1]}, max_len=S)
    outs = [lp]
    for t in range(1, S):
        lg, cache = T.decode_step(params, cfg, cache,
                                  batch["tokens"][:, t:t + 1])
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    assert err < 2e-3, err
