"""Fault-tolerance primitives (dist/ft.py): heartbeat write/read
atomicity, torn-write handling, both stall detectors (wall-clock scan and
skew-immune progress scan), and the sharding helpers. The heartbeat fault
modes are driven through the real injection plan (dist/faultinject.py) —
the same path the chaos suite and ``serve.py --fault-plan`` use."""
import json
import os
import time

import numpy as np
import pytest

from repro.dist import ft
from repro.dist.faultinject import FaultPlan


# ---------------------------------------------------------------------------
# Heartbeat writer
# ---------------------------------------------------------------------------
def test_heartbeat_roundtrip_and_seq(tmp_path):
    hb = ft.Heartbeat(str(tmp_path / "w0"), worker_id=3)
    hb.beat(10)
    hb.beat(11)
    p = hb.read()
    assert p["worker_id"] == 3 and p["step"] == 11 and p["seq"] == 2
    # atomic publish: no .tmp staging file survives a beat
    assert not os.path.exists(str(tmp_path / "w0") + ".tmp")


def test_heartbeat_read_missing_propagates(tmp_path):
    hb = ft.Heartbeat(str(tmp_path / "never_beat"))
    with pytest.raises(FileNotFoundError):
        hb.read()


def test_heartbeat_torn_payload_raises_typed(tmp_path):
    path = str(tmp_path / "w0")
    hb = ft.Heartbeat(path, fault=FaultPlan(hb_torn_at=1))
    hb.beat(0)                       # injected torn in-place write
    with open(path) as f:
        raw = f.read()
    with pytest.raises(json.JSONDecodeError):
        json.loads(raw)              # the payload really is torn
    with pytest.raises(ft.HeartbeatError):
        hb.read()
    assert "hb_torn@1" in hb.fault.fired


def test_heartbeat_skip_mode_suppresses_writes(tmp_path):
    path = str(tmp_path / "w0")
    hb = ft.Heartbeat(path, fault=FaultPlan(hb_skip_from=2))
    hb.beat(0)                       # seq 1: written
    first = hb.read()
    hb.beat(1)                       # seq 2: suppressed (wedged worker)
    hb.beat(2)                       # seq 3: suppressed
    assert hb.read() == first        # file frozen at the last real beat
    assert hb.seq == 3               # the worker *thinks* it kept beating


# ---------------------------------------------------------------------------
# detect_stalled (stateless wall-clock scan)
# ---------------------------------------------------------------------------
def test_detect_stalled_boundaries(tmp_path):
    hb = ft.Heartbeat(str(tmp_path / "w0"))
    hb.beat(0)
    assert ft.detect_stalled(str(tmp_path), deadline_s=1e-9) == ["w0"]
    assert ft.detect_stalled(str(tmp_path), deadline_s=3600) == []


def test_detect_stalled_torn_payload_falls_back_to_mtime(tmp_path):
    """A torn payload must NOT read as 'stalled since epoch': the file
    mtime (published by the same rename/write) is the liveness bound."""
    path = str(tmp_path / "w0")
    hb = ft.Heartbeat(path, fault=FaultPlan(hb_torn_at=1))
    hb.beat(0)                       # torn: unparseable JSON, fresh mtime
    assert ft.detect_stalled(str(tmp_path), deadline_s=3600) == []
    # and with an ancient mtime it IS stalled, even though unreadable
    os.utime(path, (time.time() - 7200, time.time() - 7200))
    assert ft.detect_stalled(str(tmp_path), deadline_s=3600) == ["w0"]


def test_detect_stalled_ignores_tmp_staging(tmp_path):
    (tmp_path / "w0.tmp").write_text("{in-flight rename staging}")
    assert ft.detect_stalled(str(tmp_path), deadline_s=1e-9) == []


# ---------------------------------------------------------------------------
# StallDetector (stateful, reader-clock progress scan)
# ---------------------------------------------------------------------------
def test_stall_detector_progress_and_stall(tmp_path):
    hb = ft.Heartbeat(str(tmp_path / "w0"))
    det = ft.StallDetector(str(tmp_path), deadline_s=0.05)
    hb.beat(0)
    assert det.poll() == []          # first sight starts the grace window
    hb.beat(1)
    assert det.poll() == []          # seq advanced: healthy
    time.sleep(0.08)
    assert det.poll() == ["w0"]      # no progress for > deadline
    hb.beat(2)
    assert det.poll() == []          # progress clears the stall


def test_stall_detector_immune_to_wall_clock_skew(tmp_path):
    """A beat whose wall-clock 'time' is hours in the past (writer clock
    stepped backwards) must still read as LIVE: the detector compares seq
    counters on the reader's monotonic clock, never cross-host time."""
    path = tmp_path / "w0"
    det = ft.StallDetector(str(tmp_path), deadline_s=0.05)
    for seq in (1, 2):
        path.write_text(json.dumps(
            {"worker_id": 0, "step": seq, "seq": seq,
             "time": time.time() - 9999}))
        assert det.poll() == []
    # meanwhile the wall-clock scan would misclassify this worker:
    assert ft.detect_stalled(str(tmp_path), deadline_s=3600) == ["w0"]


def test_stall_detector_torn_payload_uses_mtime_marker(tmp_path):
    path = tmp_path / "w0"
    path.write_text("{torn")
    det = ft.StallDetector(str(tmp_path), deadline_s=0.05)
    assert det.poll() == []          # grace on first sight
    time.sleep(0.08)
    assert det.poll() == ["w0"]      # mtime marker never advanced
    path.write_text("{torn again")   # fresh mtime = progress signal
    assert det.poll() == []


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_rows,shards", [(64, 4), (65, 4), (7, 3), (8, 1)])
def test_shard_rows_disjoint_cover(n_rows, shards):
    parts = [ft.shard_rows(n_rows, shards, i) for i in range(shards)]
    allrows = np.concatenate(parts)
    assert len(allrows) == n_rows == len(set(allrows.tolist()))
    assert (np.sort(allrows) == np.arange(n_rows)).all()


def test_speculative_shard_rederives_neighbor():
    assert (ft.speculative_shard(64, 4, 1, 2) == ft.shard_rows(64, 4, 3)).all()
    assert (ft.speculative_shard(64, 4, 3, 1) == ft.shard_rows(64, 4, 0)).all()
