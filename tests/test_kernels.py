"""Per-kernel interpret-mode validation: shape/dtype sweeps asserted
allclose against the pure-jnp oracles in repro/kernels/ref.py."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


def rnd(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return {"float32": 2e-5, "bfloat16": 2e-2}[jnp.dtype(dtype).name]


# ---------------------------------------------------------------------------
# lowrank_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("M,K,R,N", [
    (128, 256, 32, 256),      # aligned
    (64, 512, 128, 1024),     # bigger rank
    (100, 200, 24, 300),      # ragged everything (wrapper pads)
    (1, 256, 16, 256),        # decode-shaped single token
    (1024, 128, 8, 128),      # long m
])
def test_lowrank_matmul_sweep(M, K, R, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = rnd(ks[0], (M, K), dtype)
    B = rnd(ks[1], (K, R), dtype) * 0.1
    C = rnd(ks[2], (R, N), dtype) * 0.1
    y = ops.lowrank_matmul(x, B, C)
    yr = ref.lowrank_matmul(x, B, C)
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yr.astype(jnp.float32))))
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float32)))) + 1e-6
    assert err / scale < tol(dtype), (err, scale)


def test_lowrank_matmul_leading_dims():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = rnd(ks[0], (2, 3, 64, 128), jnp.float32)
    B = rnd(ks[1], (128, 16), jnp.float32)
    C = rnd(ks[2], (16, 96), jnp.float32)
    y = ops.lowrank_matmul(x, B, C)
    assert y.shape == (2, 3, 64, 96)
    assert jnp.allclose(y, ref.lowrank_matmul(x, B, C), atol=1e-4)


def test_lowrank_matmul_grads_match_dense_chain():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = rnd(ks[0], (8, 64), jnp.float32)
    B = rnd(ks[1], (64, 8), jnp.float32) * 0.2
    C = rnd(ks[2], (8, 32), jnp.float32) * 0.2
    g1 = jax.grad(lambda *a: jnp.sum(ops.lowrank_matmul(*a) ** 2),
                  argnums=(0, 1, 2))(x, B, C)
    g2 = jax.grad(lambda x, B, C: jnp.sum(((x @ B) @ C) ** 2),
                  argnums=(0, 1, 2))(x, B, C)
    for a, b in zip(g1, g2):
        assert jnp.allclose(a, b, atol=1e-3)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,cap", [
    (2, 128, 4, 2, 64, True, 0, 0.0),      # GQA causal
    (1, 256, 8, 8, 32, True, 64, 0.0),     # MHA sliding window
    (2, 128, 4, 1, 64, True, 0, 50.0),     # MQA + softcap
    (1, 64, 2, 2, 128, False, 0, 0.0),     # bidirectional (encoder)
    (2, 96, 6, 2, 64, True, 32, 0.0),      # ragged block sizes
    (1, 8, 4, 4, 16, True, 0, 0.0),        # tiny
])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rnd(ks[0], (B, S, H, hd), dtype)
    k = rnd(ks[1], (B, S, KV, hd), dtype)
    v = rnd(ks[2], (B, S, KV, hd), dtype)
    o = ops.flash_attention(q, k, v, causal, window, cap)
    orf = ref.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - orf.astype(jnp.float32))))
    assert err < tol(dtype), err


def test_flash_attention_grad_falls_back_to_ref():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rnd(ks[0], (1, 32, 2, 16), jnp.float32)
    k = rnd(ks[1], (1, 32, 2, 16), jnp.float32)
    v = rnd(ks[2], (1, 32, 2, 16), jnp.float32)
    g1 = jax.grad(lambda q: jnp.sum(ops.flash_attention(q, k, v) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(ref.flash_attention(q, k, v) ** 2))(q)
    assert jnp.allclose(g1, g2, atol=1e-4)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,D", [(512, 256), (1000, 200), (64, 512),
                                 (4096, 64)])
def test_gram_sweep(N, D, dtype):
    x = rnd(jax.random.PRNGKey(0), (N, D), dtype)
    g = ops.gram(x)
    gr = ref.gram(x)
    assert g.dtype == jnp.float32
    rel = float(jnp.max(jnp.abs(g - gr)) / (jnp.max(jnp.abs(gr)) + 1e-6))
    assert rel < 5e-6 if dtype == jnp.float32 else rel < 5e-2


def test_gram_leading_dims():
    x = rnd(jax.random.PRNGKey(1), (4, 32, 48), jnp.float32)
    g = ops.gram(x)
    assert g.shape == (48, 48)
    assert jnp.allclose(g, ref.gram(x.reshape(-1, 48)), atol=1e-3)


# ---------------------------------------------------------------------------
# integration: the model's use_pallas switch routes through the kernels
# ---------------------------------------------------------------------------
def test_model_pallas_path_matches_jnp():
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.params import set_use_pallas

    cfg = get_config("llama-mini").replace(n_layers=2)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                          cfg.vocab_size)}
    l0, _ = T.forward(params, cfg, batch)
    set_use_pallas(True)
    try:
        l1, _ = T.forward(params, cfg, batch)
    finally:
        set_use_pallas(False)
    assert jnp.allclose(l0, l1, atol=2e-3), float(jnp.max(jnp.abs(l0 - l1)))
