"""D-Rank core: numerics, allocator, and end-to-end compression invariants.
Property tests use hypothesis."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import allocate as alloc
from repro.core import numerics as num
from repro.core import compress as CC
from repro.configs import get_config
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Effective rank (paper §3.2.1)
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=64),
       st.floats(0.01, 100.0))
@settings(max_examples=100, deadline=None)
def test_effective_rank_properties(sigmas, scale):
    s = np.array(sigmas)
    r = num.effective_rank(s)
    # bounds: 1 <= R_eff <= #nonzero
    assert 1.0 - 1e-9 <= r <= len(s) + 1e-6
    # scale invariance
    assert np.isclose(num.effective_rank(scale * s), r, rtol=1e-6)


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=32),
       st.integers(0, 10 ** 9))
@settings(max_examples=60, deadline=None)
def test_effective_rank_permutation_invariance(sigmas, seed):
    s = np.array(sigmas)
    perm = np.random.default_rng(seed).permutation(len(s))
    assert np.isclose(num.effective_rank(s[perm]), num.effective_rank(s),
                      rtol=1e-9)


def test_effective_rank_flat_spectrum():
    for n in (1, 4, 37):
        s = np.ones(n)
        assert np.isclose(num.effective_rank(s), n, rtol=1e-6)


def test_effective_rank_single_dominant():
    s = np.array([100.0, 1e-9, 1e-9])
    assert num.effective_rank(s) < 1.001


# ---------------------------------------------------------------------------
# Cholesky whitener: damping escalation on degenerate Grams
# (deterministic counterparts always run in tests/test_numerics_properties)
# ---------------------------------------------------------------------------
@given(st.integers(4, 48), st.integers(1, 4), st.integers(0, 10 ** 9))
@settings(max_examples=40, deadline=None)
def test_cholesky_whitener_escalates_on_near_singular(d, rank_div, seed):
    """Rank-deficient Grams (calibration rows << d, even rank 1) must
    whiten without raising: damping escalates until the factorization
    succeeds, S stays upper-triangular, and S·S⁻¹ = I."""
    rng = np.random.default_rng(seed)
    rows = max(1, d // (rank_div * 2))          # rank << d
    X = rng.normal(size=(rows, d))
    G = X.T @ X
    wh = num.cholesky_whitener(G)
    assert np.isfinite(wh.S).all() and np.isfinite(wh.S_inv).all()
    assert np.allclose(wh.S, np.triu(wh.S))
    assert np.allclose(wh.S @ wh.S_inv, np.eye(d), atol=1e-6)


# ---------------------------------------------------------------------------
# Whitening optimality: the whitened truncation minimizes ‖X(W-Ŵ)‖
# ---------------------------------------------------------------------------
def test_whitened_svd_beats_plain_on_activation_loss():
    rng = np.random.default_rng(0)
    d_in, d_out, n_tok, k = 32, 48, 256, 8
    # anisotropic activations
    A = rng.normal(size=(d_in, d_in))
    X = rng.normal(size=(n_tok, d_in)) @ A
    W = rng.normal(size=(d_in, d_out))
    G = X.T @ X

    def act_err(What):
        return np.linalg.norm(X @ (W - What))

    wh = num.cholesky_whitener(G, damp=1e-9)
    U, s, Vt = num.whitened_svd(W, wh)
    B, C = num.truncate_factors(U, s, Vt, k, wh)
    whitened_err = act_err(B @ C)

    wh0 = num.identity_whitener()
    U0, s0, Vt0 = num.whitened_svd(W, wh0)
    B0, C0 = num.truncate_factors(U0, s0, Vt0, k, wh0)
    plain_err = act_err(B0 @ C0)

    assert whitened_err < plain_err * 0.999

    # full rank reproduces W exactly
    Bf, Cf = num.truncate_factors(U, s, Vt, min(d_in, d_out), wh)
    assert np.allclose(Bf @ Cf, W, atol=1e-8)


def test_whitened_truncation_is_optimal_among_rank_k():
    """Eckart–Young in the whitened metric: no random rank-k factorization
    beats the whitened SVD truncation on ‖X(W-Ŵ)‖."""
    rng = np.random.default_rng(1)
    d, m, k = 24, 24, 6
    X = rng.normal(size=(200, d)) * np.linspace(0.1, 3.0, d)
    W = rng.normal(size=(d, m))
    G = X.T @ X
    wh = num.cholesky_whitener(G, damp=1e-10)
    U, s, Vt = num.whitened_svd(W, wh)
    B, C = num.truncate_factors(U, s, Vt, k, wh)
    best = np.linalg.norm(X @ (W - B @ C))
    for seed in range(10):
        r2 = np.random.default_rng(100 + seed)
        Br = r2.normal(size=(d, k))
        # optimal C given random B (least squares in whitened space)
        M = X @ Br
        Cr = np.linalg.lstsq(M, X @ W, rcond=None)[0]
        err = np.linalg.norm(X @ (W - Br @ Cr))
        assert best <= err * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Lagrange allocator (paper eq 13-19)
# ---------------------------------------------------------------------------
def _mk_groups(reffs, omegas, kmaxes=None, dense=None):
    gs = []
    for i, (r, w) in enumerate(zip(reffs, omegas)):
        gs.append(alloc.GroupSpec(
            gid=f"g{i}", mtype="q", reff=r, omega=w,
            kmax=(kmaxes[i] if kmaxes else 10 ** 9),
            dense_params=(dense[i] if dense else w * 100)))
    return gs


@given(st.lists(st.floats(100.0, 1e4), min_size=2, max_size=12))
@settings(max_examples=60, deadline=None)
def test_lagrange_budget_and_proportionality(reffs):
    # reff range chosen so no group hits its k_min/k_max clamp — the
    # closed-form proportionality only holds for unclamped groups
    omegas = [128] * len(reffs)
    gs = _mk_groups(reffs, omegas)
    budget = 128.0 * 50 * len(reffs)
    k = alloc.lagrange_allocate(gs, budget)
    # budget met exactly (no clamps active)
    spent = sum(k[g.gid] * g.omega for g in gs)
    assert np.isclose(spent, budget, rtol=1e-6)
    # k_g proportional to sqrt(reff) at equal omega
    ks = np.array([k[f"g{i}"] for i in range(len(reffs))])
    rs = np.sqrt(np.array(reffs))
    ratio = ks / rs
    assert np.allclose(ratio, ratio[0], rtol=1e-5)


def test_lagrange_omega_inverse_sqrt():
    gs = _mk_groups([100.0, 100.0], [64, 256])
    k = alloc.lagrange_allocate(gs, 64.0 * 100 + 256.0 * 100)
    # k ∝ 1/sqrt(omega)
    assert np.isclose(k["g0"] / k["g1"], np.sqrt(256 / 64), rtol=1e-6)


def test_lagrange_clamping_redistributes():
    gs = _mk_groups([1e6, 1.0, 1.0], [10, 10, 10], kmaxes=[5, 1000, 1000])
    budget = 10.0 * 100
    k = alloc.lagrange_allocate(gs, budget)
    assert k["g0"] == 5.0
    spent = sum(k[g.gid] * g.omega for g in gs)
    assert spent <= budget * (1 + 1e-9)
    assert np.isclose(k["g1"], k["g2"], rtol=1e-6)


def test_beta_rebalance_budget_conserving_in_rank_units():
    gs = (_mk_groups([10, 10], [8, 8]) +
          [alloc.GroupSpec("gk0", "k", 10, 8, 10 ** 9, dense_params=800),
           alloc.GroupSpec("gv0", "v", 10, 8, 10 ** 9, dense_params=800)])
    gs[0].mtype = "q"
    gs[1].mtype = "q"
    k = {"g0": 10.0, "g1": 20.0, "gk0": 30.0, "gv0": 5.0}
    k2 = alloc.beta_rebalance(gs, k, beta=0.3)
    assert np.isclose(sum(k2.values()), sum(k.values()))
    assert k2["g0"] == pytest.approx(7.0)
    assert k2["gk0"] == pytest.approx(21.0)
    assert k2["gv0"] == pytest.approx(5.0 + 0.3 * 60)


def test_integerize_respects_budget_and_multiple():
    gs = _mk_groups([50.0, 500.0, 5000.0], [100, 100, 100],
                    kmaxes=[64, 64, 64], dense=[6400, 6400, 6400])
    budget = 0.8 * 3 * 6400
    kf = alloc.lagrange_allocate(gs, budget)
    ki = alloc.integerize(gs, kf, budget, multiple=8)
    assert all(v % 8 == 0 or v == gs[i].kmax
               for i, v in enumerate(ki.values()))
    assert sum(ki[g.gid] * g.omega for g in gs) <= budget
    # monotone in reff
    assert ki["g0"] <= ki["g1"] <= ki["g2"]


# ---------------------------------------------------------------------------
# End-to-end compression invariants
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mini_setup():
    cfg = get_config("llama-mini")
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                             (2, 64), 0, cfg.vocab_size)}
               for i in range(2)]
    return cfg, params, batches


@pytest.mark.slow           # heaviest sweep: 6 full compression pipelines
@pytest.mark.parametrize("method", ["svd", "asvd", "svdllm", "basis",
                                    "drank", "dranke"])
def test_methods_hit_target_ratio(mini_setup, method):
    cfg, params, batches = mini_setup
    ccfg = CC.CompressionConfig(method=method, ratio=0.3, group_size=2,
                                beta=0.3)
    new_lp, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    assert abs(plan.summary["achieved_ratio"] - 0.3) < 0.02
    # compressed model still runs and is finite
    loss, _ = T.lm_loss(new_lp, cfg, batches[0])
    assert jnp.isfinite(loss)


def test_drank_allocates_by_information(mini_setup):
    cfg, params, batches = mini_setup
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                beta=0.0)
    _, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    # within a type, higher reff => rank no smaller (weak monotonicity)
    by_type = {}
    for g in plan.groups:
        by_type.setdefault(g.mtype, []).append(g)
    checked = 0
    for t, gs in by_type.items():
        gs = sorted(gs, key=lambda g: g.reff)
        for a, b in zip(gs, gs[1:]):
            if b.reff > a.reff * 1.05 and a.k < a.kmax and b.k < b.kmax:
                assert b.k >= a.k, (t, a.gid, b.gid)
                checked += 1
    assert checked > 0


def test_fwsvd_runs(mini_setup):
    cfg, params, batches = mini_setup
    ccfg = CC.CompressionConfig(method="fwsvd", ratio=0.3)
    new_lp, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    loss, _ = T.lm_loss(new_lp, cfg, batches[0])
    assert jnp.isfinite(loss)


def test_near_lossless_at_tiny_ratio(mini_setup):
    """At ~0 compression the whitened factorization must reproduce the
    model almost exactly (Eckart-Young at full retained rank)."""
    cfg, params, batches = mini_setup
    ccfg = CC.CompressionConfig(method="drank", ratio=0.02, group_size=1,
                                beta=0.0)
    new_lp, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    l0, _ = T.lm_loss(params, cfg, batches[0])
    l1, _ = T.lm_loss(new_lp, cfg, batches[0])
    assert abs(float(l1) - float(l0)) < 0.05


def test_plan_roundtrip(mini_setup):
    cfg, params, batches = mini_setup
    ccfg = CC.CompressionConfig(method="drank", ratio=0.25)
    _, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    plan2 = CC.Plan.from_json(plan.to_json())
    assert plan2.summary == pytest.approx(plan.summary)
    assert [g.gid for g in plan2.groups] == [g.gid for g in plan.groups]


@pytest.mark.slow           # MoE sweep: per-expert capture + grouped SVDs
def test_moe_expert_compression():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(9)
    batches = [{"tokens": jax.random.randint(key, (2, 32), 0,
                                             cfg.vocab_size)}]
    ccfg = CC.CompressionConfig(method="drank", ratio=0.2, group_size=2)
    new_lp, plan = CC.build_plan_and_params(params, cfg, ccfg, batches)
    xg = [g for g in plan.groups if g.mtype.startswith("x")]
    assert len(xg) > 0, "routed experts were not compressed"
    loss, _ = T.lm_loss(new_lp, cfg, batches[0])
    assert jnp.isfinite(loss)
