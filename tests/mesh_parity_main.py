"""Mesh-parity driver for the PR-5 tentpole: runs in its OWN process with
``--xla_force_host_platform_device_count=8`` (jax locks the device count at
backend init, so an in-process pytest cannot re-mesh; see
tests/test_mesh_parity.py for the subprocess wrapper and the acceptance
bars it enforces).

Checks, all on a (data=8, model=1) host mesh against single-device
streaming capture and the eager fp64 oracle:

  1. tree-reduced whitening factor == single-shard streaming factor
     (≤1e-6 rel after diagonal sign fix — Cholesky-factor uniqueness)
     and its RᵀR == the oracle Gram
  2. sharded (D,D) accumulators: flush equality vs the replicated route,
     and the sharding-spec assertion that no device ever holds a full
     (D,D) block for sharded-route tags
  3. flush-cadence invariance under the two-stage pipelined fold
  4. plan parity: identical integer ranks and token-identical serve from
     a mesh-captured (sharded + whitened) calibration vs the eager oracle

Prints MESH_PARITY_OK on success; any assertion kills the process.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import numpy as np          # noqa: E402
import jax                  # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.core import compress as CC                       # noqa: E402
from repro.core.capture import (StreamingCalibrator,        # noqa: E402
                                streaming_calibrate, to_list_params)
from repro.launch.mesh import make_host_mesh                # noqa: E402
from repro.models import transformer as T                   # noqa: E402
from repro.serve.engine import Engine, ServeConfig          # noqa: E402

CFG = get_config("llama-mini").replace(n_layers=2, d_model=64, n_heads=4,
                                       n_kv_heads=4, head_dim=16, d_ff=128,
                                       vocab_size=256, rank_multiple=4)
REL_BAR = 1e-6


def batches(cfg, n=3, batch=8, seq=32, seed=7):
    key = jax.random.PRNGKey(seed)
    return [{"tokens": jax.random.randint(jax.random.fold_in(key, i),
                                          (batch, seq), 0, cfg.vocab_size)}
            for i in range(n)]


def rel(a, b):
    return float(np.abs(a - b).max() / (np.abs(b).max() + 1e-12))


def sign_fix(R):
    s = np.sign(np.diag(R)).copy()
    s[s == 0] = 1.0
    return s[:, None] * R


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = make_host_mesh(data=8, model=1)
    params, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    lp = to_list_params(params, CFG)
    bs = batches(CFG)
    oracle = CC.calibrate(lp, CFG, bs, streaming=False)

    # -- 1. tree-reduced whitening factors ---------------------------------
    col1 = streaming_calibrate(lp, CFG, bs, whiten_tags=True)
    col8 = streaming_calibrate(lp, CFG, bs, mesh=mesh, whiten_tags=True)
    assert set(col8.chol) == set(col1.chol) and not col8.gram
    worst_r = worst_g = 0.0
    for tag in col1.chol:
        worst_r = max(worst_r, rel(sign_fix(col8.chol[tag]),
                                   sign_fix(col1.chol[tag])))
        worst_g = max(worst_g, rel(col8.chol[tag].T @ col8.chol[tag],
                                   oracle.gram[tag]))
    assert worst_r <= REL_BAR, f"tree-reduced factor drifted: {worst_r:.2e}"
    print(f"  [1] tree-reduced factor vs 1-shard chain: {worst_r:.1e} rel "
          f"(RᵀR vs fp64 oracle Gram: {worst_g:.1e})")

    # -- 2. sharded accumulators: spec assertion + flush equality ----------
    cal = StreamingCalibrator(lp, CFG, mesh=mesh, shard_grams_above=1)
    for b in bs:
        cal.ingest(b)
    assert set(cal.routes.values()) == {"sharded"}, cal.routes
    for tag, acc in cal._accs.items():
        g = acc["gram"]
        local = g.sharding.shard_shape(g.shape)
        assert local[0] * 8 == g.shape[0] and local[1] == g.shape[1], (
            tag, local, g.shape)      # row block only, never a full (D,D)
        assert len(g.addressable_shards) == 8
    col_sh = cal.finalize()
    col_rep = streaming_calibrate(lp, CFG, bs, mesh=mesh)   # replicated
    worst = 0.0
    for tag in oracle.gram:
        worst = max(worst, rel(col_sh.gram[tag], col_rep.gram[tag]),
                    rel(col_sh.gram[tag], oracle.gram[tag]))
        assert col_sh.count[tag] == oracle.count[tag]
    assert worst <= 1e-5, f"sharded-accumulator flush diverged: {worst:.2e}"
    print(f"  [2] sharded vs replicated accumulator flush: {worst:.1e} rel "
          f"(specs row-sharded 8-way on every tag)")

    # -- 3. flush-cadence invariance under the pipelined fold --------------
    col_f1 = streaming_calibrate(lp, CFG, bs, mesh=mesh, flush_every=1,
                                 shard_grams_above=1)
    worst = max(rel(col_f1.gram[t], col_sh.gram[t]) for t in col_f1.gram)
    assert worst <= 1e-6, f"flush cadence changed sharded sums: {worst:.2e}"
    print(f"  [3] flush-cadence invariance (pipelined fold): {worst:.1e}")

    # -- 4. identical ranks + token-identical serve ------------------------
    ccfg = CC.CompressionConfig(method="drank", ratio=0.3, group_size=2,
                                beta=0.3)
    col_mesh = streaming_calibrate(lp, CFG, bs, mesh=mesh,
                                   shard_grams_above=1,
                                   whiten_tags={t for t in oracle.gram
                                                if "/wq" in t})
    comp_o, plan_o = CC.build_plan_and_params(params, CFG, ccfg, bs,
                                              collector=oracle)
    comp_m, plan_m = CC.build_plan_and_params(params, CFG, ccfg, bs,
                                              collector=col_mesh)
    ranks_o = {g.gid: g.k for g in plan_o.groups}
    ranks_m = {g.gid: g.k for g in plan_m.groups}
    assert ranks_m == ranks_o, {k: (ranks_o[k], ranks_m[k])
                                for k in ranks_o if ranks_o[k] != ranks_m[k]}
    prompts = (np.arange(24, dtype=np.int32).reshape(2, 12)
               % CFG.vocab_size)
    out_o = Engine(comp_o, CFG, ServeConfig()).generate(prompts, n_new=12)
    out_m = Engine(comp_m, CFG, ServeConfig()).generate(prompts, n_new=12)
    assert (out_o == out_m).all()
    print(f"  [4] mesh-captured plan: {len(ranks_m)} groups, ranks "
          f"identical, serve token-identical")
    print("MESH_PARITY_OK")


if __name__ == "__main__":
    main()
