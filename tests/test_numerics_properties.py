"""Deterministic property sweeps + golden-value allocation fixtures.

tests/test_core.py holds the hypothesis versions of the property tests,
but that whole module is skipped when hypothesis isn't installed (the dev
container doesn't ship it) — these seeded sweeps cover the same
properties unconditionally, and the goldens pin the allocator's numeric
behavior (lagrange_allocate / beta_rebalance / integerize) to
hand-checked expected ranks so allocation changes can't drift silently.
"""
import numpy as np
import pytest

from repro.core import allocate as alloc
from repro.core import numerics as num

SEEDS = range(25)


# ---------------------------------------------------------------------------
# effective_rank properties (paper §3.2.1)
# ---------------------------------------------------------------------------
def test_effective_rank_bounds_and_scale_invariance():
    for seed in SEEDS:
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 64))
        s = rng.uniform(0.1, 100.0, size=n)
        r = num.effective_rank(s)
        assert 1.0 - 1e-9 <= r <= n + 1e-6
        scale = float(rng.uniform(0.01, 100.0))
        assert np.isclose(num.effective_rank(scale * s), r, rtol=1e-6)


def test_effective_rank_permutation_invariance():
    for seed in SEEDS:
        rng = np.random.default_rng(seed)
        s = rng.uniform(0.1, 100.0, size=int(rng.integers(2, 32)))
        perm = rng.permutation(len(s))
        assert np.isclose(num.effective_rank(s[perm]),
                          num.effective_rank(s), rtol=1e-9)


# ---------------------------------------------------------------------------
# cholesky_whitener: damping escalation on degenerate Grams
# ---------------------------------------------------------------------------
def test_cholesky_whitener_escalates_on_near_singular():
    for seed in SEEDS:
        rng = np.random.default_rng(seed)
        d = int(rng.integers(4, 48))
        rows = max(1, d // int(rng.integers(2, 9)))     # rank << d
        X = rng.normal(size=(rows, d))
        wh = num.cholesky_whitener(X.T @ X)
        assert np.isfinite(wh.S).all() and np.isfinite(wh.S_inv).all()
        assert np.allclose(wh.S, np.triu(wh.S))
        assert np.allclose(wh.S @ wh.S_inv, np.eye(d), atol=1e-6)


def test_cholesky_whitener_zero_gram():
    wh = num.cholesky_whitener(np.zeros((8, 8)))
    assert np.isfinite(wh.S).all()


def test_cholesky_whitener_rejects_non_finite_gram():
    """NaN/inf Grams must fail loudly with a diagnostic (some LAPACK
    builds return a NaN factor without raising), not whiten garbage."""
    with pytest.raises(np.linalg.LinAlgError, match="non-finite"):
        num.cholesky_whitener(np.full((4, 4), np.nan))


def test_cholesky_whitener_error_reports_taus_and_condition(monkeypatch):
    """When escalation runs out, the error must carry the taus tried and
    the Gram's condition estimate — not a bare LinAlgError."""
    def always_fail(_):
        raise np.linalg.LinAlgError("potrf")
    monkeypatch.setattr(np.linalg, "cholesky", always_fail)
    G = np.diag([1.0, 1e-12])
    with pytest.raises(np.linalg.LinAlgError) as ei:
        num.cholesky_whitener(G)
    msg = str(ei.value)
    assert "12 damping escalations" in msg
    assert "taus tried" in msg
    assert "condition estimate" in msg and "eig range" in msg


def test_whitener_from_factor_matches_cholesky():
    rng = np.random.default_rng(11)
    X = rng.normal(size=(200, 24))
    G = X.T @ X
    R = np.linalg.qr(X, mode="r")               # RᵀR = G, streaming form
    wh = num.whitener_from_factor(R)
    ref = num.cholesky_whitener(G, damp=1e-12)
    assert np.allclose(np.abs(wh.S), np.abs(ref.S), rtol=1e-6, atol=1e-8)
    assert np.allclose(wh.S @ wh.S_inv, np.eye(24), atol=1e-8)


# ---------------------------------------------------------------------------
# Golden-value allocation fixtures (hand-checked expected ranks)
# ---------------------------------------------------------------------------
def _spec(gid, mtype, reff, omega, kmax=10 ** 9, kmin=1, dense=10 ** 6):
    return alloc.GroupSpec(gid=gid, mtype=mtype, reff=reff, omega=omega,
                           kmax=kmax, kmin=kmin, dense_params=dense)


def test_golden_lagrange_sqrt_reff_proportionality():
    """Equal omega ⇒ k ∝ √reff. reff 100 vs 400 at budget 30·128:
    denom = √(100·128)+√(400·128) = 3·√12800, C = 3840/denom,
    k0 = C·√(100/128) = 10, k1 = 2·k0 = 20. Hand-checked."""
    gs = [_spec("g0", "q", 100.0, 128), _spec("g1", "q", 400.0, 128)]
    k = alloc.lagrange_allocate(gs, 128.0 * 30)
    assert k["g0"] == pytest.approx(10.0, rel=1e-9)
    assert k["g1"] == pytest.approx(20.0, rel=1e-9)


def test_golden_lagrange_inverse_sqrt_omega():
    """Equal reff ⇒ k ∝ 1/√omega and budget is met exactly:
    omegas 64/256 at budget 32000 ⇒ k = (500/3, 250/3). Hand-checked:
    C = 32000/(√6400+√25600) = 400/3, k0 = C·1.25, k1 = C·0.625."""
    gs = [_spec("g0", "q", 100.0, 64), _spec("g1", "q", 100.0, 256)]
    k = alloc.lagrange_allocate(gs, 32000.0)
    assert k["g0"] == pytest.approx(500.0 / 3.0, rel=1e-9)
    assert k["g1"] == pytest.approx(250.0 / 3.0, rel=1e-9)
    assert 64 * k["g0"] + 256 * k["g1"] == pytest.approx(32000.0)


def test_golden_lagrange_kmax_clamp_redistributes():
    """g0 hits kmax=5 in pass 1 (its unclamped share would be ≈83); the
    freed budget re-solves over g1/g2: (1000 − 5·10)/(2·10) = 47.5 each.
    Hand-checked water-filling."""
    gs = [_spec("g0", "q", 1e6, 10, kmax=5),
          _spec("g1", "q", 1e4, 10), _spec("g2", "q", 1e4, 10)]
    k = alloc.lagrange_allocate(gs, 1000.0)
    assert k["g0"] == 5.0
    assert k["g1"] == pytest.approx(47.5, rel=1e-9)
    assert k["g2"] == pytest.approx(47.5, rel=1e-9)


def test_golden_beta_rebalance_qk_to_v():
    """β=0.25 moves a quarter of each Q/K rank to V, split evenly:
    q=10 → 7.5, k=8 → 6, extracted 4.5 → v=4+4.5=8.5. Hand-checked
    (paper eq 9–12); o is not a donor or receiver and must not move."""
    gs = [_spec("gq", "q", 10, 8), _spec("gk", "k", 10, 8),
          _spec("gv", "v", 10, 8), _spec("go", "o", 10, 8)]
    k = {"gq": 10.0, "gk": 8.0, "gv": 4.0, "go": 6.0}
    out = alloc.beta_rebalance(gs, k, beta=0.25)
    assert out == {"gq": 7.5, "gk": 6.0, "gv": 8.5, "go": 6.0}
    assert sum(out.values()) == pytest.approx(sum(k.values()))


def test_golden_beta_rebalance_receiver_kmax_cap():
    """The V receiver clamps at its kmax (8): 4 + 4.5 would exceed it."""
    gs = [_spec("gq", "q", 10, 8), _spec("gk", "k", 10, 8),
          _spec("gv", "v", 10, 8, kmax=8)]
    out = alloc.beta_rebalance(gs, {"gq": 10.0, "gk": 8.0, "gv": 4.0},
                               beta=0.25)
    assert out["gv"] == 8.0


def test_golden_integerize_round_to_multiple_within_budget():
    """Targets (12.4, 27.6), multiple 8, omega 10:
    budget 400 → round-to-nearest (16, 24) costs exactly 400; the grow
    step can't afford +8·10. Hand-checked greedy trace."""
    gs = [_spec("g0", "q", 50.0, 10, kmax=100, dense=1000),
          _spec("g1", "q", 50.0, 10, kmax=100, dense=1000)]
    out = alloc.integerize(gs, {"g0": 12.4, "g1": 27.6}, 400.0, multiple=8)
    assert out == {"g0": 16, "g1": 24}


def test_golden_integerize_budget_repair_shrinks_most_over():
    """Same targets at budget 320: (16, 24) costs 400 > 320, g0 is the
    relatively most-over-target ((16−12.4)/12.4 ≈ 0.29) so it shrinks by
    one multiple → (8, 24) = 320 exactly. Hand-checked greedy trace."""
    gs = [_spec("g0", "q", 50.0, 10, kmax=100, dense=1000),
          _spec("g1", "q", 50.0, 10, kmax=100, dense=1000)]
    out = alloc.integerize(gs, {"g0": 12.4, "g1": 27.6}, 320.0, multiple=8)
    assert out == {"g0": 8, "g1": 24}
    assert sum(out[g.gid] * g.omega for g in gs) <= 320.0


def test_golden_integerize_topup_spends_leftover():
    """Multiple=1, targets (10.2, 20.2), budget 32·10: rounding gives
    (10, 20) = 300; the top-up loop spends the leftover 20 on the
    relatively most-compressed groups one step at a time → (11, 21)."""
    gs = [_spec("g0", "q", 50.0, 10, kmax=100, dense=1000),
          _spec("g1", "q", 50.0, 10, kmax=100, dense=1000)]
    out = alloc.integerize(gs, {"g0": 10.2, "g1": 20.2}, 320.0, multiple=1)
    assert out == {"g0": 11, "g1": 21}
    assert sum(out[g.gid] * g.omega for g in gs) <= 320.0