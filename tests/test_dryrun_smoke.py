"""Dry-run machinery smoke test: lower_cell on a small fake-device mesh in
a subprocess (the real 512-device sweep runs via repro.launch.dryrun; this
guards the machinery — input specs, shardings, HLO analyzer — in CI)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


@pytest.mark.slow
def test_lower_cell_small_mesh_subprocess():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro.launch.mesh import make_host_mesh
        from repro.launch import dryrun as DR

        mesh = make_host_mesh(4, 2)
        # the paper-family arch at tiny shape: override shape table
        from repro.config import SHAPES, ShapeConfig
        SHAPES["tiny_train"] = ShapeConfig("tiny_train", 128, 8, "train")
        SHAPES["tiny_decode"] = ShapeConfig("tiny_decode", 128, 8, "decode")
        out = {}
        for shape in ("tiny_train", "tiny_decode"):
            res = DR.lower_cell("smollm-360m", shape, mesh,
                                overrides={"n_layers": 4})
            assert "error" not in res, res.get("error")
            r = res["roofline"]
            out[shape] = {"flops": res["cost"]["hlo_flops"],
                          "coll": res["collectives"]["total_bytes"],
                          "dominant": r["dominant"]}
        print(json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    data = json.loads(res.stdout.strip().splitlines()[-1])
    assert data["tiny_train"]["flops"] > 1e9
    assert data["tiny_train"]["coll"] > 0       # sharded => collectives


def test_hlo_analyzer_units():
    from repro.launch import hlo_analysis as HA
    hlo = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant({...})
  %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    out = HA.analyze(hlo)
    # 5 iterations x (2*8*8*8) flops
    assert out["flops"] == 5 * 2 * 8 * 8 * 8, out
    assert out["collective_bytes"] == 0
