"""Paged KV cache (ISSUE 9, DESIGN.md §5.7).

The load-bearing oracle is the CONTIGUOUS per-slot pool: under every
workload — mixed-length bucketed admission, elastic rung transitions,
the seeded chaos suite — the paged pool must produce EXACTLY the same
tokens (greedy decode is deterministic; the paged gather reproduces the
contiguous cache value-for-value). Prefix reuse adds its own oracle: a
request that shares refcounted blocks (including a copy-on-write fork)
must decode identically to one that prefilled its whole prompt, and a
poison purge of a sharing request must free its private blocks without
touching the shared ones other holders still read.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compress as CC
from repro.dist import faultinject as FI
from repro.models import transformer as T
from repro.serve import admission as adm
from repro.serve import paged as pglib
from repro.serve.engine import ContinuousBatcher, Request, ServeConfig

CFG = get_config("llama-mini").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
CONTIG = ServeConfig(batch=4, max_len=64)
PAGED = ServeConfig(batch=4, max_len=64, kv_block=16)
SHARED = ServeConfig(batch=4, max_len=64, kv_block=16, prefix_cache=True)


@pytest.fixture(scope="module")
def params():
    p, _ = T.init_model(CFG, jax.random.PRNGKey(0))
    return p


@pytest.fixture(scope="module")
def comp(params):
    calib = [{"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)}]
    cfg = CFG.replace(rank_multiple=1)
    c, _ = CC.build_plan_and_params(
        params, cfg, CC.CompressionConfig(ratio=0.4), calib)
    return c


def make_requests(n=6, n_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, n_new=n_new,
                    tokens=rng.integers(0, CFG.vocab_size, size=(7,),
                                        dtype=np.int32))
            for i in range(n)]


def clone(reqs):
    return [Request(rid=r.rid, tokens=np.array(r.tokens), n_new=r.n_new,
                    deadline_s=r.deadline_s) for r in reqs]


def drive(params, reqs, scfg, *, stagger=0, **kw):
    """Submit (optionally interleaving engine steps every ``stagger``
    requests — the SAME schedule for oracle and paged runs) and drain."""
    cb = ContinuousBatcher(params, CFG, scfg, **kw)
    for i, r in enumerate(reqs):
        cb.submit(r)
        if stagger and i % stagger == stagger - 1:
            cb.step()
    return cb, cb.run_until_drained()


def outs(res):
    return {r.rid: list(r.out) for r in res}


def assert_pool_drained(cb):
    """Every block returned: no leak survives a full drain."""
    assert cb.pool.in_use == 0
    assert (cb.table == 0).all()
    assert not cb._req_blocks


# ---------------------------------------------------------------------------
# BlockPool / PrefixCache units
# ---------------------------------------------------------------------------
def test_block_pool_alloc_free_refcount():
    pool = pglib.BlockPool(9)                   # 8 usable + null block 0
    assert pool.in_use == 0
    assert pool.can_alloc(8) and not pool.can_alloc(9)
    a = pool.alloc(3)
    assert a is not None and len(set(a)) == 3 and 0 not in a
    assert pool.in_use == 3
    assert pool.alloc(6) is None                # only 5 left...
    assert pool.in_use == 3                     # ...and the miss is a no-op
    pool.incref(a[0])
    assert not pool.decref(a[0])                # ref 2 -> 1: still held
    assert pool.decref(a[0])                    # ref 1 -> 0: freed
    for b in a[1:]:
        assert pool.decref(b)
    assert pool.in_use == 0 and pool.peak_in_use == 3


def test_block_pool_is_deterministic():
    p1, p2 = pglib.BlockPool(8), pglib.BlockPool(8)
    assert p1.alloc(3) == p2.alloc(3)
    a, b = p1.alloc(2), p2.alloc(2)
    assert a == b
    for x in a:
        p1.decref(x)
    for x in b:
        p2.decref(x)
    assert p1.alloc(4) == p2.alloc(4)           # LIFO free-list reuse


def _seeded_cache(bk=4):
    """One registered prompt: 2 full blocks (+1 private tail block)."""
    pool = pglib.BlockPool(12)
    cache = pglib.PrefixCache(bk)
    toks = np.arange(10, dtype=np.int32)        # blocks [0..3], [4..7], tail
    blocks = pool.alloc(3)
    row = np.zeros((8,), dtype=np.int32)
    row[:3] = blocks
    cache.register(toks, row, pool)             # publishes the 2 full blocks
    return pool, cache, toks, blocks


def test_prefix_cache_plan_full_and_cow():
    pool, cache, toks, blocks = _seeded_cache()
    plan = cache.plan(toks)                     # exact same prompt
    assert [e.block for e in plan.shared] == blocks[:2]
    assert plan.start == 8 and plan.cow_len == 0
    # diverge INSIDE block 1: tokens 4,5 match then 99 != 6 -> COW d=2
    t2 = np.array([0, 1, 2, 3, 4, 5, 99, 98, 97, 96], dtype=np.int32)
    p2 = cache.plan(t2)
    assert [e.block for e in p2.shared] == blocks[:1]
    assert p2.cow_src == blocks[1] and p2.cow_len == 2
    assert p2.start == 6
    # diverge at the first token: nothing shared
    p3 = cache.plan(np.array([7, 7, 7, 7, 7], dtype=np.int32))
    assert p3.shared == [] and p3.start == 0 and p3.cow_len == 0


def test_prefix_cache_evicts_leaves_first_then_roots():
    pool, cache, _, blocks = _seeded_cache()
    for b in blocks:                            # the request retires
        pool.decref(b)
    assert pool.in_use == 2                     # cache still pins 2 entries
    assert cache.evict_lru(pool) and pool.in_use == 1
    assert cache.evict_lru(pool) and pool.in_use == 0
    assert not cache.evict_lru(pool)            # nothing evictable left


def test_prefix_cache_evict_blocks_drops_orphans():
    pool, cache, _, blocks = _seeded_cache()
    for b in blocks:
        pool.decref(b)
    # evicting the ROOT block must also drop its now-orphaned child
    assert cache.evict_blocks([blocks[0]], pool) == 2
    assert pool.in_use == 0
    assert cache.plan(np.arange(10, dtype=np.int32)).shared == []


# ---------------------------------------------------------------------------
# Paged vs contiguous token identity
# ---------------------------------------------------------------------------
def test_paged_matches_contiguous_mixed_lengths(params):
    """Mixed prompt lengths across many bucketed admission rounds: the
    block-table pool is invisible in the output stream, and every block
    comes back after the drain."""
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i, n_new=4,
                    tokens=rng.integers(0, CFG.vocab_size,
                                        size=(int(rng.integers(1, 40)),),
                                        dtype=np.int32))
            for i in range(10)]
    cb0, r0 = drive(params, clone(reqs), CONTIG, stagger=3)
    cb1, r1 = drive(params, reqs, PAGED, stagger=3)
    assert r0.status == r1.status == "drained"
    assert outs(r1) == outs(r0)
    assert cb1.pool.peak_in_use > 0
    assert_pool_drained(cb1)
    m = cb1.metrics()
    assert m["gauges"]["kv_blocks_in_use"] == 0
    assert m["gauges"]["kv_blocks_peak"] == cb1.pool.peak_in_use


def test_paged_elastic_rungs_token_identity(comp):
    """Elastic degradation flips decode params mid-flight; the paged
    decode must ride every rung transition token-identically."""
    acfg = adm.AdmissionConfig(elastic=True, elastic_levels=2,
                               degrade_above=4, restore_below=1)
    cb0, r0 = drive(comp, make_requests(n=16), CONTIG, admission=acfg)
    cb1, r1 = drive(comp, make_requests(n=16), PAGED, admission=acfg)
    assert r0.status == r1.status == "drained"
    m0, m1 = cb0.metrics(), cb1.metrics()
    assert set(m1["rank_residency"]) > {"0"}    # actually degraded
    assert m1["rank_residency"] == m0["rank_residency"]
    assert outs(r1) == outs(r0)
    assert_pool_drained(cb1)


# ---------------------------------------------------------------------------
# Chaos suite on the paged pool
# ---------------------------------------------------------------------------
CHAOS = [
    dict(nan_decode_step=2, nan_rows=(1,)),     # pinned single decode row
    dict(seed=7, nan_decode_step=3),            # seeded row choice
    dict(nan_prefill_admission=0, nan_rows=(0,)),   # poisoned prefill
    dict(nan_decode_step=1, nan_rows="all"),    # ambiguous -> bisection
]


@pytest.mark.parametrize("spec", CHAOS)
def test_paged_chaos_token_identity(params, spec):
    """Every injected fault: the paged run completes with EXACTLY the
    contiguous run's tokens and identical resilience metrics (admission
    rounds match, so the seeded injectors fire on the same rows)."""
    cb0, r0 = drive(params, make_requests(), CONTIG,
                    faults=FI.FaultPlan(**spec))
    plan = FI.FaultPlan(**spec)
    cb1, r1 = drive(params, make_requests(), PAGED, faults=plan)
    assert r0.status == r1.status == "drained"
    assert plan.fired                           # the injector really fired
    assert outs(r1) == outs(r0)
    m0, m1 = cb0.metrics(), cb1.metrics()
    for k in ("poison_events", "poison_retries", "poison_failures",
              "slot_purges", "completed"):
        assert m1[k] == m0[k], k
    assert_pool_drained(cb1)


def test_paged_persistent_poison_fails_typed(params):
    """A content-poisoned request exhausts its budget and fails typed on
    the paged pool too — its blocks freed, batch-mates unharmed."""
    acfg = adm.AdmissionConfig(max_retries=1)
    cb0, r0 = drive(params, make_requests(), CONTIG,
                    faults=FI.FaultPlan(poison_rids=(2,)), admission=acfg)
    cb1, r1 = drive(params, make_requests(), PAGED,
                    faults=FI.FaultPlan(poison_rids=(2,)), admission=acfg)
    assert [r.rid for r in r1.failed] == [2]
    assert r1.failed[0].status == adm.FAILED_POISON
    assert cb1.metrics()["poison_failures"] == 1
    assert outs(r1) == outs(r0)
    assert_pool_drained(cb1)


# ---------------------------------------------------------------------------
# Prefix reuse
# ---------------------------------------------------------------------------
def _prefix_workload(seed=5):
    """3 requests: r0 seeds the cache (2 full blocks), r1 reuses the
    header block exactly, r2 matches 5 tokens INTO r0's second block —
    a copy-on-write fork. Divergence tokens are forced distinct so the
    hit/miss/fork counters are deterministic."""
    rng = np.random.default_rng(seed)
    V = CFG.vocab_size
    H = rng.integers(0, V, size=(16,), dtype=np.int32)      # 1 full block
    A = rng.integers(0, V, size=(16,), dtype=np.int32)      # r0's block 1
    t0 = np.concatenate([H, A, rng.integers(0, V, size=(1,),
                                            dtype=np.int32)])
    tailB = rng.integers(0, V, size=(10,), dtype=np.int32)
    tailB[0] = (A[0] + 1) % V                   # no accidental COW match
    tailC = rng.integers(0, V, size=(9,), dtype=np.int32)
    tailC[0] = (A[5] + 1) % V                   # diverge at A[5]
    return [Request(rid=0, n_new=4, tokens=t0),
            Request(rid=1, n_new=4, tokens=np.concatenate([H, tailB])),
            Request(rid=2, n_new=4,
                    tokens=np.concatenate([H, A[:5], tailC]))]


def drive_staggered(params, reqs, scfg, **kw):
    """r0 first (admitted + registered), then the sharers."""
    cb = ContinuousBatcher(params, CFG, scfg, **kw)
    cb.submit(reqs[0])
    cb.step()
    for r in reqs[1:]:
        cb.submit(r)
    return cb, cb.run_until_drained()


def test_prefix_reuse_token_identity_and_refcounts(params):
    reqs = _prefix_workload()
    cb0, r0 = drive_staggered(params, clone(reqs), CONTIG)
    cb1, r1 = drive_staggered(params, reqs, SHARED)
    assert r0.status == r1.status == "drained"
    assert outs(r1) == outs(r0)                 # sharing is invisible
    m = cb1.metrics()
    assert m["prefix_misses"] == 1              # r0 seeded the cache
    assert m["prefix_hits"] == 2                # r1 (exact), r2 (COW)
    assert m["cow_forks"] == 1                  # r2 forked r0's block 1
    # after the drain only the 2 published entries still pin blocks;
    # evicting them returns the pool to empty — refcounted frees balance
    assert cb1.pool.in_use == 2
    assert cb1.prefix.evict_lru(cb1.pool)
    assert cb1.prefix.evict_lru(cb1.pool)
    assert not cb1.prefix.evict_lru(cb1.pool)
    assert cb1.pool.in_use == 0
    assert not cb1._req_blocks


def test_poison_purge_spares_shared_prefix_blocks(params):
    """rid 1 (sharing r0's header block) is content-poisoned and fails
    typed at admission. Its purge zeroes ONLY its private blocks: r0 —
    mid-decode through the shared header — and r2 — admitted in the same
    round, COW-forked off the same cache — finish token-identically to
    the clean run."""
    reqs = _prefix_workload()
    _, clean = drive_staggered(params, clone(reqs), CONTIG)
    cb, res = drive_staggered(params, reqs, SHARED,
                              faults=FI.FaultPlan(poison_rids=(1,)),
                              admission=adm.AdmissionConfig(max_retries=0))
    assert res.status == "drained"
    assert [r.rid for r in res.failed] == [1]
    assert cb.metrics()["poison_failures"] == 1
    want = outs(clean)
    assert outs(res) == {0: want[0], 2: want[2]}


# ---------------------------------------------------------------------------
# Purge-then-reuse (the length-0 block-0 regression)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scfg", [CONTIG, PAGED],
                         ids=["contiguous", "paged"])
def test_purge_then_reuse_slot_bit_identical(params, scfg):
    """A freshly purged slot must behave exactly like a never-used one:
    the decode step used to address row/block 0 for length-0 rows, so a
    purged slot's stale cache could leak into its next occupant."""
    rng = np.random.default_rng(21)
    bad = Request(rid=0, n_new=3,
                  tokens=rng.integers(0, CFG.vocab_size, size=(12,),
                                      dtype=np.int32))
    good_t = rng.integers(0, CFG.vocab_size, size=(9,), dtype=np.int32)
    cb0 = ContinuousBatcher(params, CFG, scfg)      # fresh-engine oracle
    cb0.submit(Request(rid=1, n_new=3, tokens=good_t.copy()))
    want = outs(cb0.run_until_drained())[1]
    cb = ContinuousBatcher(params, CFG, scfg,
                           faults=FI.FaultPlan(poison_rids=(0,)),
                           admission=adm.AdmissionConfig(max_retries=0))
    cb.submit(bad)
    cb.step()                       # admit -> poison -> purge slot 0
    assert [r.rid for r in cb.failed] == [0]
    cb.submit(Request(rid=1, n_new=3, tokens=good_t.copy()))
    res = cb.run_until_drained()
    assert res.status == "drained"
    assert outs(res)[1] == want     # slot 0 reused, bit-identical


# ---------------------------------------------------------------------------
# Over-long prompt policy (truncation counted / strict shedding)
# ---------------------------------------------------------------------------
def test_overlong_prompt_truncation_is_counted(params):
    rng = np.random.default_rng(31)
    long_t = rng.integers(0, CFG.vocab_size, size=(80,), dtype=np.int32)
    cb = ContinuousBatcher(params, CFG, CONTIG)
    req = Request(rid=0, n_new=3, tokens=long_t.copy())
    cb.submit(req)
    res = cb.run_until_drained()
    assert res.status == "drained" and len(res) == 1
    assert req.truncated and len(req.tokens) == CONTIG.max_len - 1
    assert (req.tokens == long_t[-(CONTIG.max_len - 1):]).all()
    assert cb.metrics()["prompt_truncations"] == 1
    # the kept-newest-tokens run equals a request submitted pre-truncated
    cb2 = ContinuousBatcher(params, CFG, CONTIG)
    cb2.submit(Request(rid=0, n_new=3,
                       tokens=long_t[-(CONTIG.max_len - 1):].copy()))
    assert outs(cb2.run_until_drained()) == outs(res)


def test_reject_overlong_sheds_typed(params):
    rng = np.random.default_rng(32)
    cb = ContinuousBatcher(params, CFG, CONTIG,
                           admission=adm.AdmissionConfig(
                               reject_overlong=True))
    long_req = Request(rid=0, n_new=3,
                       tokens=rng.integers(0, CFG.vocab_size, size=(80,),
                                           dtype=np.int32))
    ok_req = Request(rid=1, n_new=3,
                     tokens=rng.integers(0, CFG.vocab_size, size=(8,),
                                         dtype=np.int32))
    cb.submit(long_req)
    cb.submit(ok_req)
    res = cb.run_until_drained()
    assert res.status == "drained"
    assert [r.rid for r in res] == [1]          # short request unharmed
    assert long_req.status == adm.SHED_OVERLONG
    assert long_req in res.shed
    m = cb.metrics()
    assert m["shed_overlong"] == 1 and m["prompt_truncations"] == 0
